//! Live drift-aware autotuner over the replica pool — the paper's
//! runtime model tuning turned into a serving-scale *policy*.
//!
//! [`super::tuner::RecalibrationLoop`] (Fig 8) is an offline loop: one
//! service, pre-cut windows, fixed shape.  This module is the live
//! version, and it talks **only** to a [`ServiceHandle`] — the policy
//! code never owns an engine, so everything it does (probe, swap,
//! rollback) goes through the same versioned, panic-supervised request
//! path that serves traffic.  Three layers:
//!
//! 1. **Streaming telemetry** — [`Autotuner::observe_window`] probes a
//!    labeled trickle *through the pool* ([`ServiceHandle::infer_telemetry`]),
//!    yielding windowed accuracy plus a label-free confidence-margin
//!    signal (top-1 minus top-2 class sum).  [`DriftDetector`] applies
//!    hysteresis: a single noisy window never triggers a retune storm —
//!    drift must be *sustained* for `patience` consecutive windows.
//! 2. **Budget-constrained shape search** — on sustained drift a
//!    shadow retrain runs (on a background thread in live mode) over
//!    the recent labeled corpus: candidate shapes from
//!    [`super::hyperparam::SearchSpace::around`] are trained and costed
//!    through [`crate::model_cost::resources::estimate`] +
//!    [`crate::model_cost::energy::EnergyModel`]; the winner is the most
//!    accurate model whose *fitted* deployment the caller-supplied
//!    [`ResourceBudget`] admits (the paper's runtime model-size tuning
//!    with an explicit LUT/BRAM/energy frontier).
//! 3. **Zero-downtime swap** — the winner is hot-swapped via
//!    [`ServiceHandle::program`] (the version fence: traffic never
//!    observes a mixed-version pool), and if post-swap windowed
//!    accuracy regresses against the trigger-time accuracy the previous
//!    model is restored — versions stay strictly monotone either way.

use std::sync::{mpsc, Arc};

use crate::config::TMShape;
use crate::datasets::synth::{Dataset, SynthSpec};
use crate::model_cost::energy::EnergyModel;
use crate::model_cost::resources::{estimate, fitted_config, ResourceBudget};
use crate::tm::model::TMModel;

use super::hyperparam::{budget_search, BudgetedSearch, SearchSpace};
use super::server::{ServeError, ServiceHandle};

/// One monitored serving window, as seen through the pool.
#[derive(Debug, Clone)]
pub struct WindowStats {
    /// Labeled-trickle accuracy (None for an unlabeled window).
    pub accuracy: Option<f64>,
    /// Mean confidence margin (top-1 minus top-2 class sum).
    pub mean_margin: f64,
    pub samples: usize,
    /// Pool model version that served the window.
    pub model_version: u64,
}

/// Hysteresis-gated drift detector — the pure policy core, shared by
/// the live autotuner and the offline [`super::tuner::RecalibrationLoop`]
/// (which wraps it with `patience = 1`).
///
/// A window is *bad* when labeled accuracy falls below
/// `accuracy_floor`, or — labels or not — when the mean margin
/// collapses below `margin_frac` of the healthy baseline (an EWMA over
/// good windows).  Drift is *sustained* once `patience` consecutive
/// windows are bad.
#[derive(Debug, Clone)]
pub struct DriftDetector {
    pub accuracy_floor: f64,
    /// Margin-collapse threshold as a fraction of the healthy baseline.
    pub margin_frac: f64,
    /// Consecutive bad windows required before drift is declared.
    pub patience: usize,
    baseline_margin: Option<f64>,
    consecutive_bad: usize,
}

impl DriftDetector {
    pub fn new(accuracy_floor: f64, patience: usize) -> Self {
        DriftDetector {
            accuracy_floor,
            margin_frac: 0.5,
            patience: patience.max(1),
            baseline_margin: None,
            consecutive_bad: 0,
        }
    }

    /// Feed one window; true when drift is sustained.
    pub fn push(&mut self, accuracy: Option<f64>, mean_margin: f64) -> bool {
        let margin_bad = self
            .baseline_margin
            .map(|b| mean_margin < self.margin_frac * b)
            .unwrap_or(false);
        let bad = match accuracy {
            Some(a) => a < self.accuracy_floor || margin_bad,
            None => margin_bad,
        };
        if bad {
            self.consecutive_bad += 1;
        } else {
            self.consecutive_bad = 0;
            // Healthy window: update the margin baseline (EWMA).
            self.baseline_margin = Some(match self.baseline_margin {
                None => mean_margin,
                Some(b) => 0.75 * b + 0.25 * mean_margin,
            });
        }
        self.consecutive_bad >= self.patience
    }

    /// Forget the bad streak (after a retune resolved it) but keep the
    /// learned margin baseline.
    pub fn reset(&mut self) {
        self.consecutive_bad = 0;
    }

    /// Forget the streak AND the learned margin baseline.  Required
    /// after an accepted swap to a different shape: the new model's
    /// healthy margins can be structurally smaller than the old
    /// model's, and a stale baseline would flag every window as
    /// collapsed — a perpetual retune storm.  The baseline re-forms
    /// from the next healthy windows.
    pub fn rebaseline(&mut self) {
        self.consecutive_bad = 0;
        self.baseline_margin = None;
    }

    pub fn consecutive_bad(&self) -> usize {
        self.consecutive_bad
    }
}

/// Produces the replacement model once drift is confirmed.  The default
/// is [`BudgetSearchTrainer`]; tests inject fixed/bad trainers to drive
/// the rollback and budget-gate paths deterministically.
pub trait ShadowTrainer: Send + Sync {
    fn retrain(&self, train: &Dataset, valid: &Dataset) -> BudgetedSearch;
}

/// The default shadow trainer: [`budget_search`] over
/// [`SearchSpace::around`] the deployed shape.
pub struct BudgetSearchTrainer {
    pub shape: TMShape,
    pub budget: ResourceBudget,
    pub epochs: usize,
    pub seed: u64,
}

impl ShadowTrainer for BudgetSearchTrainer {
    fn retrain(&self, train: &Dataset, valid: &Dataset) -> BudgetedSearch {
        let mut space = SearchSpace::around(&self.shape);
        space.epochs = self.epochs;
        space.seed = self.seed;
        budget_search(&self.shape, train, valid, &space, &self.budget)
    }
}

/// Autotuner policy knobs.
#[derive(Debug, Clone)]
pub struct AutotuneConfig {
    /// Windowed labeled accuracy below this marks a window bad.
    pub accuracy_floor: f64,
    /// Consecutive bad windows before drift is declared (hysteresis).
    pub patience: usize,
    /// Margin-collapse fraction vs. the healthy baseline.
    pub margin_frac: f64,
    /// Resource frontier a swapped-in model must fit.
    pub budget: ResourceBudget,
    /// Shadow-retrain epochs / PRNG seed (deterministic).
    pub epochs: usize,
    pub seed: u64,
    /// Post-swap windows averaged before the swap is judged.
    pub validation_windows: usize,
    /// The swap is kept if mean post-swap accuracy beats the
    /// trigger-time accuracy by at least this much, OR simply reaches
    /// `accuracy_floor` (a margin-triggered retune can fire at high
    /// labeled accuracy, where "trigger + gain" would be unreachable);
    /// otherwise the previous model is restored.
    pub min_gain: f64,
    /// Run the shadow search on a background thread (live mode).  When
    /// false the search runs inline in `observe_window` — the
    /// deterministic mode unit tests and the offline wrapper use.
    pub background: bool,
    /// Most-recent labeled samples retained as the retrain corpus.
    pub retrain_corpus: usize,
}

impl AutotuneConfig {
    pub fn new(budget: ResourceBudget) -> Self {
        AutotuneConfig {
            accuracy_floor: 0.85,
            patience: 2,
            margin_frac: 0.5,
            budget,
            epochs: 3,
            seed: 17,
            validation_windows: 1,
            min_gain: 0.05,
            background: true,
            retrain_corpus: 1024,
        }
    }
}

/// Decision log of one autotuned deployment.
#[derive(Debug, Clone)]
pub enum AutotuneEvent {
    DriftDetected { window: usize, accuracy: f64, mean_margin: f64 },
    SearchCompleted { window: usize, trials: usize, admitted: usize },
    /// The search's winner (or an injected trainer's output) failed the
    /// budget gate at swap time and was NOT programmed.
    BudgetRejected { window: usize, luts: u32, brams: u32, watts: f64 },
    /// No candidate fit the budget; the pool keeps the old model.
    NoCandidateFitsBudget { window: usize },
    /// The shadow-search thread died; monitoring resumes.
    SearchFailed { window: usize },
    /// A swap could not be carried through: the pool rejected the
    /// broadcast (e.g. the candidate overflows the replicas' ACTUAL
    /// memory depths — the budget costs the fitted deployment, not the
    /// pool's spec; the previously serving model was re-programmed, so
    /// the outage is one fence, never permanent), or a regression was
    /// detected with no recorded previous model to roll back to.
    SwapFailed { window: usize, error: String },
    Swapped {
        window: usize,
        version: u64,
        trigger_accuracy: f64,
        instructions: usize,
        luts: u32,
        brams: u32,
        watts: f64,
    },
    Accepted { window: usize, mean_accuracy: f64 },
    RolledBack { window: usize, mean_accuracy: f64, version: u64 },
}

/// Telemetry + decisions of one autotuned deployment.
#[derive(Debug, Clone, Default)]
pub struct AutotuneReport {
    pub windows: Vec<WindowStats>,
    pub events: Vec<AutotuneEvent>,
}

#[derive(Debug, Copy, Clone)]
enum Phase {
    Monitoring,
    Searching { trigger_accuracy: f64 },
    Validating {
        trigger_accuracy: f64,
        windows_left: usize,
        acc_sum: f64,
        n: usize,
    },
}

enum SearchPoll {
    Pending,
    Done(BudgetedSearch),
    Died,
}

/// The live autotuner.  Owns nothing but a [`ServiceHandle`]: every
/// probe and every swap goes through the serving pool's request path.
pub struct Autotuner {
    handle: ServiceHandle,
    shape: TMShape,
    cfg: AutotuneConfig,
    trainer: Arc<dyn ShadowTrainer>,
    detector: DriftDetector,
    phase: Phase,
    /// Rollback target: what the pool ran before the last swap.
    previous: Option<Arc<TMModel>>,
    current: Option<Arc<TMModel>>,
    pending: Option<mpsc::Receiver<BudgetedSearch>>,
    corpus_xs: Vec<Vec<u8>>,
    corpus_ys: Vec<usize>,
    window_index: usize,
    /// True when the default budget search is in use: an accepted swap
    /// then re-anchors the search around the NEW shape.  Injected
    /// trainers ([`Self::with_trainer`]) are never replaced.
    reanchor: bool,
    pub report: AutotuneReport,
}

impl Autotuner {
    /// Autotuner with the default budget-constrained shadow search
    /// around `shape`.
    pub fn new(handle: ServiceHandle, shape: TMShape, cfg: AutotuneConfig) -> Self {
        let trainer = Arc::new(BudgetSearchTrainer {
            shape: shape.clone(),
            budget: cfg.budget.clone(),
            epochs: cfg.epochs,
            seed: cfg.seed,
        });
        let mut tuner = Self::with_trainer(handle, shape, cfg, trainer);
        tuner.reanchor = true;
        tuner
    }

    /// Autotuner with an injected shadow trainer (tests, custom search
    /// strategies).  The budget gate still applies at swap time; the
    /// injected trainer is kept across swaps (no re-anchoring).
    pub fn with_trainer(
        handle: ServiceHandle,
        shape: TMShape,
        cfg: AutotuneConfig,
        trainer: Arc<dyn ShadowTrainer>,
    ) -> Self {
        let detector = DriftDetector {
            margin_frac: cfg.margin_frac,
            ..DriftDetector::new(cfg.accuracy_floor, cfg.patience)
        };
        Autotuner {
            handle,
            shape,
            cfg,
            trainer,
            detector,
            phase: Phase::Monitoring,
            previous: None,
            current: None,
            pending: None,
            corpus_xs: Vec::new(),
            corpus_ys: Vec::new(),
            window_index: 0,
            reanchor: false,
            report: AutotuneReport::default(),
        }
    }

    /// Program the initial model (recorded as the first rollback
    /// baseline).
    pub fn install(&mut self, model: TMModel) -> Result<(), ServeError> {
        let m = Arc::new(model);
        self.handle.program((*m).clone())?;
        self.current = Some(m);
        Ok(())
    }

    /// Model the autotuner believes the pool is serving.
    pub fn current_model(&self) -> Option<&TMModel> {
        self.current.as_deref()
    }

    pub fn is_searching(&self) -> bool {
        matches!(self.phase, Phase::Searching { .. })
    }

    pub fn phase_name(&self) -> &'static str {
        match self.phase {
            Phase::Monitoring => "monitoring",
            Phase::Searching { .. } => "searching",
            Phase::Validating { .. } => "validating",
        }
    }

    /// Feed one labeled monitoring window.  The probe goes through the
    /// serving pool (it IS traffic); the state machine then advances:
    /// detect → (shadow search) → swap → validate/rollback.
    pub fn observe_window(
        &mut self,
        xs: &[Vec<u8>],
        ys: &[usize],
    ) -> Result<WindowStats, ServeError> {
        // A row/label mismatch would silently skew accuracy AND shift
        // every later corpus label against its sample — reject it
        // before anything is recorded.
        if xs.len() != ys.len() {
            return Err(ServeError::Core(crate::accel::core::CoreError::BadBatch {
                rows: xs.len(),
                reason: "window labels do not match rows",
            }));
        }
        let tel = self.handle.infer_telemetry(xs.to_vec())?;
        let correct = tel.preds.iter().zip(ys).filter(|(p, y)| p == y).count();
        let accuracy = correct as f64 / xs.len().max(1) as f64;
        let mean_margin = tel.margins.iter().map(|&m| m as f64).sum::<f64>()
            / tel.margins.len().max(1) as f64;
        let stats = WindowStats {
            accuracy: Some(accuracy),
            mean_margin,
            samples: xs.len(),
            model_version: tel.model_version,
        };
        self.report.windows.push(stats.clone());

        // Retrain corpus: most recent labeled samples, capped.
        self.corpus_xs.extend_from_slice(xs);
        self.corpus_ys.extend_from_slice(ys);
        let cap = self.cfg.retrain_corpus.max(1);
        if self.corpus_xs.len() > cap {
            let drop = self.corpus_xs.len() - cap;
            self.corpus_xs.drain(..drop);
            self.corpus_ys.drain(..drop);
        }

        self.step(accuracy, mean_margin)?;
        self.window_index += 1;
        Ok(stats)
    }

    /// Block until a pending shadow search finishes and act on it.
    /// Returns true if a search was pending.  Serving traffic continues
    /// on the pool the whole time — only the policy thread waits.
    pub fn finish_pending_search(&mut self) -> Result<bool, ServeError> {
        let Phase::Searching { trigger_accuracy } = self.phase else {
            return Ok(false);
        };
        match self.poll_search(true) {
            SearchPoll::Done(outcome) => {
                self.finish_search(outcome, trigger_accuracy)?;
                Ok(true)
            }
            SearchPoll::Died => {
                self.search_died();
                Ok(true)
            }
            SearchPoll::Pending => unreachable!("blocking poll never returns Pending"),
        }
    }

    fn step(&mut self, accuracy: f64, mean_margin: f64) -> Result<(), ServeError> {
        match self.phase {
            Phase::Monitoring => {
                if self.detector.push(Some(accuracy), mean_margin) {
                    self.report.events.push(AutotuneEvent::DriftDetected {
                        window: self.window_index,
                        accuracy,
                        mean_margin,
                    });
                    self.launch_search(accuracy)?;
                }
            }
            Phase::Searching { trigger_accuracy } => match self.poll_search(false) {
                SearchPoll::Pending => {}
                SearchPoll::Done(outcome) => self.finish_search(outcome, trigger_accuracy)?,
                SearchPoll::Died => self.search_died(),
            },
            Phase::Validating { trigger_accuracy, windows_left, acc_sum, n } => {
                let acc_sum = acc_sum + accuracy;
                let n = n + 1;
                if windows_left <= 1 {
                    let mean = acc_sum / n as f64;
                    // Healthy is good enough: a margin-triggered retune
                    // can have trigger_accuracy near 1.0, where
                    // "trigger + gain" is unreachable and would doom
                    // every swap to rollback (a retrain-rollback loop).
                    let kept = mean >= trigger_accuracy + self.cfg.min_gain
                        || mean >= self.cfg.accuracy_floor;
                    if !kept {
                        // The retrain did not help: restore the previous
                        // model (another fence-gated program — versions
                        // stay strictly monotone).
                        match self.previous.clone() {
                            Some(prev) => {
                                self.handle.program((*prev).clone())?;
                                self.current = Some(prev);
                                self.report.events.push(AutotuneEvent::RolledBack {
                                    window: self.window_index,
                                    mean_accuracy: mean,
                                    version: self.handle.pool_stats().version,
                                });
                            }
                            // Nothing to restore (the pool was programmed
                            // behind the tuner's back): record honestly —
                            // the regressing model keeps serving, NOT a
                            // phantom rollback.
                            None => self.report.events.push(AutotuneEvent::SwapFailed {
                                window: self.window_index,
                                error: format!(
                                    "regression (mean accuracy {mean:.3}) with no previous \
                                     model to roll back to"
                                ),
                            }),
                        }
                        // The old model is back (or was never recorded):
                        // the margin baseline stays, only the streak
                        // clears.
                        self.detector.reset();
                    } else {
                        self.report.events.push(AutotuneEvent::Accepted {
                            window: self.window_index,
                            mean_accuracy: mean,
                        });
                        // A different shape serves now; its healthy
                        // margin scale may differ — re-learn it.
                        self.detector.rebaseline();
                        // And re-anchor the default shadow search to the
                        // ACCEPTED shape, so the next retune explores the
                        // deployed model's neighborhood, not the
                        // install-time one.
                        if self.reanchor {
                            if let Some(cur) = &self.current {
                                self.shape = cur.shape.clone();
                                self.trainer = Arc::new(BudgetSearchTrainer {
                                    shape: cur.shape.clone(),
                                    budget: self.cfg.budget.clone(),
                                    epochs: self.cfg.epochs,
                                    seed: self.cfg.seed,
                                });
                            }
                        }
                    }
                    self.phase = Phase::Monitoring;
                } else {
                    self.phase = Phase::Validating {
                        trigger_accuracy,
                        windows_left: windows_left - 1,
                        acc_sum,
                        n,
                    };
                }
            }
        }
        Ok(())
    }

    fn corpus_dataset(&self) -> Dataset {
        let features = self.corpus_xs.first().map(|r| r.len()).unwrap_or(0);
        Dataset {
            xs: self.corpus_xs.clone(),
            ys: self.corpus_ys.clone(),
            spec: SynthSpec::new(features, self.shape.classes, self.corpus_xs.len()),
        }
    }

    fn launch_search(&mut self, trigger_accuracy: f64) -> Result<(), ServeError> {
        let (train, valid) = self.corpus_dataset().split(0.75);
        self.phase = Phase::Searching { trigger_accuracy };
        if self.cfg.background {
            let trainer = Arc::clone(&self.trainer);
            let (tx, rx) = mpsc::channel();
            std::thread::Builder::new()
                .name("rttm-autotune-search".into())
                .spawn(move || {
                    let _ = tx.send(trainer.retrain(&train, &valid));
                })
                .expect("spawn shadow-search thread");
            self.pending = Some(rx);
        } else {
            let outcome = self.trainer.retrain(&train, &valid);
            self.finish_search(outcome, trigger_accuracy)?;
        }
        Ok(())
    }

    fn poll_search(&mut self, block: bool) -> SearchPoll {
        let Some(rx) = self.pending.as_ref() else {
            return SearchPoll::Died;
        };
        let polled = if block {
            rx.recv().map_err(|_| mpsc::TryRecvError::Disconnected)
        } else {
            rx.try_recv()
        };
        match polled {
            Ok(outcome) => {
                self.pending = None;
                SearchPoll::Done(outcome)
            }
            Err(mpsc::TryRecvError::Empty) => SearchPoll::Pending,
            Err(mpsc::TryRecvError::Disconnected) => {
                self.pending = None;
                SearchPoll::Died
            }
        }
    }

    fn search_died(&mut self) {
        self.report.events.push(AutotuneEvent::SearchFailed { window: self.window_index });
        self.detector.reset();
        self.phase = Phase::Monitoring;
    }

    fn finish_search(
        &mut self,
        outcome: BudgetedSearch,
        trigger_accuracy: f64,
    ) -> Result<(), ServeError> {
        let admitted = outcome.trials.iter().filter(|t| t.admitted).count();
        self.report.events.push(AutotuneEvent::SearchCompleted {
            window: self.window_index,
            trials: outcome.trials.len(),
            admitted,
        });
        let Some(model) = outcome.winner else {
            self.report.events.push(AutotuneEvent::NoCandidateFitsBudget {
                window: self.window_index,
            });
            self.detector.reset();
            self.phase = Phase::Monitoring;
            return Ok(());
        };
        // Budget gate at the swap, independent of how the model was
        // produced: trainers are pluggable, the frontier is not.  A
        // candidate exceeding the budget is never programmed.
        let deploy = fitted_config(&model);
        let est = estimate(&deploy);
        let watts = EnergyModel::for_config(&deploy).watts;
        if !self.cfg.budget.admits(&est, watts) {
            self.report.events.push(AutotuneEvent::BudgetRejected {
                window: self.window_index,
                luts: est.luts,
                brams: est.brams,
                watts,
            });
            self.detector.reset();
            self.phase = Phase::Monitoring;
            return Ok(());
        }
        let instructions = crate::isa::instruction_count(&model);
        let m = Arc::new(model);
        if let Err(e) = self.handle.program((*m).clone()) {
            // The broadcast failed — a failed swap deliberately leaves
            // replicas UNPROGRAMMED (never stale), so the serving model
            // must be restored right here or the pool is a permanent
            // outage.  The restore re-programs what was serving a
            // moment ago, so it fits the replicas' memories.
            if let Some(cur) = self.current.clone() {
                self.handle.program((*cur).clone())?;
            }
            self.report.events.push(AutotuneEvent::SwapFailed {
                window: self.window_index,
                error: e.to_string(),
            });
            self.detector.reset();
            self.phase = Phase::Monitoring;
            return Ok(());
        }
        self.previous = self.current.clone();
        self.current = Some(m);
        self.report.events.push(AutotuneEvent::Swapped {
            window: self.window_index,
            version: self.handle.pool_stats().version,
            trigger_accuracy,
            instructions,
            luts: est.luts,
            brams: est.brams,
            watts,
        });
        self.phase = Phase::Validating {
            trigger_accuracy,
            windows_left: self.cfg.validation_windows.max(1),
            acc_sum: 0.0,
            n: 0,
        };
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::server::spawn_pool;
    use crate::coordinator::EngineSpec;
    use crate::datasets::synth::SynthSpec;
    use crate::TMShape;

    fn shape() -> TMShape {
        TMShape::synthetic(12, 3, 8)
    }

    fn dataset(drift: f64, n: usize, seed: u64) -> Dataset {
        SynthSpec::new(12, 3, n).noise(0.05).seed(seed).drift(drift).generate()
    }

    fn trained(data: &Dataset) -> TMModel {
        crate::trainer::train_model(&shape(), data, 4, 2)
    }

    // ---- hysteresis: pure DriftDetector state machine ----------------

    #[test]
    fn hysteresis_table_driven() {
        // (accuracy, margin, expect_triggered) with floor .8, patience 2.
        let cases: &[(&str, &[(f64, f64, bool)])] = &[
            (
                "single bad window never triggers",
                &[(0.95, 10.0, false), (0.40, 2.0, false), (0.95, 10.0, false)],
            ),
            (
                "two consecutive bad windows trigger",
                &[(0.95, 10.0, false), (0.40, 2.0, false), (0.42, 2.0, true)],
            ),
            (
                "non-consecutive bad windows never trigger",
                &[
                    (0.40, 2.0, false),
                    (0.95, 10.0, false),
                    (0.40, 2.0, false),
                    (0.95, 10.0, false),
                    (0.40, 2.0, false),
                ],
            ),
            (
                "healthy stream never triggers",
                &[(0.92, 9.0, false), (0.97, 11.0, false), (0.93, 10.0, false)],
            ),
        ];
        for (name, seq) in cases {
            let mut d = DriftDetector::new(0.8, 2);
            for (i, &(acc, margin, expect)) in seq.iter().enumerate() {
                assert_eq!(
                    d.push(Some(acc), margin),
                    expect,
                    "case {name:?}, window {i}"
                );
            }
        }
    }

    #[test]
    fn margin_collapse_triggers_without_labels() {
        let mut d = DriftDetector::new(0.8, 2);
        // Establish a healthy baseline margin ~10.
        assert!(!d.push(Some(0.95), 10.0));
        assert!(!d.push(Some(0.96), 10.0));
        // Unlabeled windows with collapsed margins must still trigger.
        assert!(!d.push(None, 2.0));
        assert!(d.push(None, 2.0));
        // And unlabeled windows with healthy margins must not.
        let mut d = DriftDetector::new(0.8, 2);
        assert!(!d.push(Some(0.95), 10.0));
        assert!(!d.push(None, 9.0));
        assert!(!d.push(None, 11.0));
        assert_eq!(d.consecutive_bad(), 0);
    }

    #[test]
    fn reset_clears_streak_not_baseline() {
        let mut d = DriftDetector::new(0.8, 3);
        assert!(!d.push(Some(0.9), 10.0));
        assert!(!d.push(Some(0.5), 2.0));
        assert!(!d.push(Some(0.5), 2.0));
        d.reset();
        assert_eq!(d.consecutive_bad(), 0);
        // Margin baseline survived: collapse still counts as bad.
        assert!(!d.push(None, 2.0));
        assert!(!d.push(None, 2.0));
        assert!(d.push(None, 2.0));
    }

    #[test]
    fn mismatched_window_labels_are_rejected_before_recording() {
        let clean = dataset(0.0, 64, 7);
        let good = trained(&clean);
        let mut cfg = AutotuneConfig::new(ResourceBudget::unlimited());
        cfg.background = false;
        let (mut tuner, mut join) = autotuner_on_pool(cfg, Arc::new(EmptySearchTrainer));
        tuner.install(good).unwrap();
        let short_ys = &clean.ys[..63];
        assert!(matches!(
            tuner.observe_window(&clean.xs, short_ys),
            Err(crate::coordinator::ServeError::Core(
                crate::accel::core::CoreError::BadBatch { rows: 64, .. }
            ))
        ));
        // Nothing was recorded: no window, no corpus desync.
        assert!(tuner.report.windows.is_empty());
        let ok = tuner.observe_window(&clean.xs, &clean.ys).unwrap();
        assert_eq!(ok.samples, 64);
        tuner.handle.shutdown();
        join.join();
    }

    #[test]
    fn rebaseline_forgets_margin_baseline() {
        let mut d = DriftDetector::new(0.8, 2);
        assert!(!d.push(Some(0.9), 20.0)); // baseline 20
        d.rebaseline();
        // Margins at half the OLD baseline are healthy, not collapsed:
        // no baseline exists until a new good window establishes one.
        assert!(!d.push(Some(0.9), 8.0));
        assert!(!d.push(Some(0.9), 8.0));
        assert_eq!(d.consecutive_bad(), 0);
        // The new baseline is the new scale: collapse is judged vs 8.
        assert!(!d.push(None, 3.0));
        assert!(d.push(None, 3.0));
    }

    // ---- injected trainers --------------------------------------------

    /// Returns a fixed model as the search winner (one synthetic trial).
    struct FixedTrainer(TMModel);

    impl ShadowTrainer for FixedTrainer {
        fn retrain(&self, _train: &Dataset, _valid: &Dataset) -> BudgetedSearch {
            let cfg = fitted_config(&self.0);
            let est = estimate(&cfg);
            let watts = EnergyModel::for_config(&cfg).watts;
            BudgetedSearch {
                trials: vec![crate::coordinator::hyperparam::BudgetedTrial {
                    t: self.0.shape.t,
                    s: self.0.shape.s,
                    clauses: self.0.shape.clauses,
                    accuracy: 0.0,
                    instructions: crate::isa::instruction_count(&self.0),
                    estimate: est,
                    watts,
                    admitted: true,
                }],
                winner: Some(self.0.clone()),
            }
        }
    }

    fn autotuner_on_pool(
        cfg: AutotuneConfig,
        trainer: Arc<dyn ShadowTrainer>,
    ) -> (Autotuner, crate::coordinator::PoolJoin) {
        let (handle, join) = spawn_pool(EngineSpec::base(), 1);
        (Autotuner::with_trainer(handle, shape(), cfg, trainer), join)
    }

    // ---- rollback: injected bad retrain restores the old model --------

    #[test]
    fn rollback_restores_previous_model_with_monotone_versions() {
        let clean = dataset(0.0, 256, 7);
        let drifted = dataset(0.35, 256, 7);
        let good = trained(&clean);

        // The "retrained" model is untrained: tautology killers only,
        // predicts class 0 everywhere — guaranteed regression.
        let bad = TMModel::empty(shape());

        let mut cfg = AutotuneConfig::new(ResourceBudget::unlimited());
        cfg.patience = 2;
        cfg.accuracy_floor = 0.85;
        cfg.validation_windows = 1;
        cfg.min_gain = 0.4; // force the regression judgment
        cfg.background = false; // deterministic inline search
        let (mut tuner, mut join) = autotuner_on_pool(cfg, Arc::new(FixedTrainer(bad)));
        tuner.install(good.clone()).unwrap();

        let before = tuner.handle.infer(clean.xs.clone()).unwrap();

        // Healthy, then sustained drift (trigger), then one validation
        // window under the bad swap → rollback.
        tuner.observe_window(&clean.xs, &clean.ys).unwrap();
        tuner.observe_window(&drifted.xs, &drifted.ys).unwrap();
        tuner.observe_window(&drifted.xs, &drifted.ys).unwrap(); // trigger + swap
        tuner.observe_window(&drifted.xs, &drifted.ys).unwrap(); // validate → rollback

        let swapped = tuner
            .report
            .events
            .iter()
            .any(|e| matches!(e, AutotuneEvent::Swapped { .. }));
        let rolled = tuner
            .report
            .events
            .iter()
            .any(|e| matches!(e, AutotuneEvent::RolledBack { .. }));
        assert!(swapped, "bad model must first be swapped in: {:?}", tuner.report.events);
        assert!(rolled, "regressing swap must roll back: {:?}", tuner.report.events);

        // Previous model restored: same predictions as before the swap.
        let after = tuner.handle.infer(clean.xs.clone()).unwrap();
        assert_eq!(before, after);
        assert_eq!(tuner.current_model().unwrap(), &good);

        // Versions strictly monotone: install(1) → swap(2) → rollback(3).
        assert_eq!(tuner.handle.pool_stats().version, 3);
        tuner.handle.shutdown();
        join.join();
    }

    // ---- budget gate: over-budget candidate never programmed ----------

    #[test]
    fn over_budget_candidate_is_never_programmed() {
        let clean = dataset(0.0, 256, 7);
        let drifted = dataset(0.35, 256, 7);
        let good = trained(&clean);

        // Impossible LUT budget: whatever the trainer returns must be
        // rejected at the swap gate.
        let mut cfg = AutotuneConfig::new(ResourceBudget::unlimited().with_luts(1));
        cfg.patience = 2;
        cfg.validation_windows = 1;
        cfg.background = false;
        let candidate = trained(&drifted);
        let (mut tuner, mut join) = autotuner_on_pool(cfg, Arc::new(FixedTrainer(candidate)));
        tuner.install(good.clone()).unwrap();

        tuner.observe_window(&drifted.xs, &drifted.ys).unwrap();
        tuner.observe_window(&drifted.xs, &drifted.ys).unwrap(); // trigger

        assert!(tuner
            .report
            .events
            .iter()
            .any(|e| matches!(e, AutotuneEvent::BudgetRejected { .. })));
        assert!(!tuner
            .report
            .events
            .iter()
            .any(|e| matches!(e, AutotuneEvent::Swapped { .. })));
        // Only the install ever programmed the pool.
        assert_eq!(tuner.handle.pool_stats().version, 1);
        assert_eq!(tuner.current_model().unwrap(), &good);
        // Back to monitoring: the tuner is not wedged.
        assert_eq!(tuner.phase_name(), "monitoring");
        tuner.handle.shutdown();
        join.join();
    }

    // ---- failed swap broadcast restores the serving model -------------

    #[test]
    fn failed_swap_restores_the_serving_model() {
        use crate::accel::core::AccelConfig;

        let clean = dataset(0.0, 256, 7);
        let drifted = dataset(0.35, 256, 7);
        let good = trained(&clean);

        // Pool memories sized EXACTLY for the serving model; the
        // candidate is bigger, so the broadcast itself fails even
        // though an unlimited budget admits its fitted deployment.
        let n_small = crate::isa::instruction_count(&good);
        let big_shape = TMShape::synthetic(12, 3, 48);
        let big_data = SynthSpec::new(12, 3, 256).noise(0.05).seed(9).generate();
        let big = crate::trainer::train_model(&big_shape, &big_data, 4, 2);
        assert!(crate::isa::instruction_count(&big) > n_small, "test premise");

        let mut cfg = AutotuneConfig::new(ResourceBudget::unlimited());
        cfg.patience = 1;
        cfg.background = false;
        let spec = EngineSpec::custom(AccelConfig::base().with_depths(n_small, 2048));
        let (handle, mut join) = spawn_pool(spec, 2);
        let mut tuner = Autotuner::with_trainer(handle, shape(), cfg, Arc::new(FixedTrainer(big)));
        tuner.install(good.clone()).unwrap();
        let before = tuner.handle.infer(clean.xs.clone()).unwrap();

        // Trigger → swap broadcast fails → old model restored.
        tuner.observe_window(&drifted.xs, &drifted.ys).unwrap();

        assert!(tuner
            .report
            .events
            .iter()
            .any(|e| matches!(e, AutotuneEvent::SwapFailed { .. })));
        // NOT a permanent outage: the pool still serves the old model.
        assert_eq!(tuner.handle.infer(clean.xs.clone()).unwrap(), before);
        assert_eq!(tuner.current_model().unwrap(), &good);
        assert_eq!(tuner.phase_name(), "monitoring");
        // install(1) + failed broadcast(2) + restore(3): monotone.
        assert_eq!(tuner.handle.pool_stats().version, 3);
        tuner.handle.shutdown();
        join.join();
    }

    // ---- no-winner search resumes monitoring --------------------------

    struct EmptySearchTrainer;

    impl ShadowTrainer for EmptySearchTrainer {
        fn retrain(&self, _train: &Dataset, _valid: &Dataset) -> BudgetedSearch {
            BudgetedSearch { trials: Vec::new(), winner: None }
        }
    }

    #[test]
    fn no_candidate_resumes_monitoring() {
        let clean = dataset(0.0, 128, 7);
        let drifted = dataset(0.35, 128, 7);
        let good = trained(&clean);
        let mut cfg = AutotuneConfig::new(ResourceBudget::unlimited());
        cfg.patience = 1;
        cfg.background = false;
        let (mut tuner, mut join) = autotuner_on_pool(cfg, Arc::new(EmptySearchTrainer));
        tuner.install(good).unwrap();
        tuner.observe_window(&drifted.xs, &drifted.ys).unwrap();
        assert!(tuner
            .report
            .events
            .iter()
            .any(|e| matches!(e, AutotuneEvent::NoCandidateFitsBudget { .. })));
        assert_eq!(tuner.phase_name(), "monitoring");
        assert_eq!(tuner.handle.pool_stats().version, 1);
        tuner.handle.shutdown();
        join.join();
    }
}
