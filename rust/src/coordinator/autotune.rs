//! Live drift-aware autotuner over the replica pool — the paper's
//! runtime model tuning turned into a serving-scale *policy*.
//!
//! [`super::tuner::RecalibrationLoop`] (Fig 8) is an offline loop: one
//! service, pre-cut windows, fixed shape.  This module is the live
//! version, and it talks **only** to a [`ServiceHandle`] — the policy
//! code never owns an engine, so everything it does (probe, swap,
//! rollback) goes through the same versioned, panic-supervised request
//! path that serves traffic.  Three layers:
//!
//! 1. **Streaming telemetry** — [`Autotuner::observe_window`] probes a
//!    labeled trickle *through the pool* ([`ServiceHandle::infer_telemetry`]),
//!    yielding windowed accuracy plus a label-free confidence-margin
//!    signal (top-1 minus top-2 class sum).  [`DriftDetector`] applies
//!    hysteresis: a single noisy window never triggers a retune storm —
//!    drift must be *sustained* for `patience` consecutive windows.
//! 2. **Budget-constrained shape search** — on sustained drift a
//!    shadow retrain runs (on a background thread in live mode) over
//!    the recent labeled corpus: candidate shapes from
//!    [`super::hyperparam::SearchSpace::around`] are trained and costed
//!    through [`crate::model_cost::resources::estimate`] +
//!    [`crate::model_cost::energy::EnergyModel`]; the winner is the most
//!    accurate model whose *fitted* deployment the caller-supplied
//!    [`ResourceBudget`] admits (the paper's runtime model-size tuning
//!    with an explicit LUT/BRAM/energy frontier).
//! 3. **Staged swap through the canary gate** — the winner is first
//!    programmed onto exactly ONE replica
//!    ([`ServiceHandle::program_canary`]; live traffic routes away from
//!    it), a fraction of each subsequent window is mirrored to the
//!    canary and a baseline replica, and a sequential comparison over
//!    the paired windows ([`super::canary::CanaryController`]) renders
//!    the verdict: **promote** broadcasts the candidate to the rest of
//!    the pool behind the version fence, **reject** reprograms the lone
//!    canary back — a bad candidate is never served from more than one
//!    replica.  Pools too small to spare a replica fall back to the
//!    direct fence-gated swap.  Post-swap validation windows still
//!    guard the promoted model: a regression restores the previous one.
//!    Versions stay strictly monotone through every path.
//!
//! The whole loop runs **label-free** when it has to: unlabeled windows
//! ([`Autotuner::observe_unlabeled`]) judge drift on confidence margins
//! alone, the canary compares T-normalized margins, and labels that
//! arrive late ([`Autotuner::backfill_labels`]) backfill accuracy into
//! the [`AutotuneReport`] and the retrain corpus without re-triggering.
//!
//! On a multi-tenant pool the tuner is scoped per model for free: hand
//! it a route-scoped handle ([`ServiceHandle::with_model`]) and every
//! probe, canary stage and swap it performs targets that tenant only —
//! one `Autotuner` instance per registered model, constrained by that
//! model's own [`ResourceBudget`] from the registry, with no
//! cross-tenant traffic or reprograms.

use std::sync::{mpsc, Arc};

use crate::config::TMShape;
use crate::datasets::synth::{Dataset, SynthSpec};
use crate::model_cost::energy::EnergyModel;
use crate::model_cost::resources::{estimate, fitted_config, ResourceBudget};
use crate::tm::model::TMModel;

use super::canary::{CanaryConfig, CanaryController, CanaryVerdict, PairedWindow};
use super::hyperparam::{budget_search, BudgetedSearch, SearchSpace};
use super::server::{ServeError, ServiceHandle, Telemetry};

/// One monitored serving window, as seen through the pool.
#[derive(Debug, Clone)]
pub struct WindowStats {
    /// Labeled-trickle accuracy (None for an unlabeled window).
    pub accuracy: Option<f64>,
    /// Mean confidence margin (top-1 minus top-2 class sum).
    pub mean_margin: f64,
    pub samples: usize,
    /// Pool model version that served the window.
    pub model_version: u64,
}

/// Hysteresis-gated drift detector — the pure policy core, shared by
/// the live autotuner and the offline [`super::tuner::RecalibrationLoop`]
/// (which wraps it with `patience = 1`).
///
/// A window is *bad* when labeled accuracy falls below
/// `accuracy_floor`, or — labels or not — when the mean margin
/// collapses below `margin_frac` of the healthy baseline (an EWMA over
/// good windows).  Drift is *sustained* once `patience` consecutive
/// windows are bad.
#[derive(Debug, Clone)]
pub struct DriftDetector {
    pub accuracy_floor: f64,
    /// Margin-collapse threshold as a fraction of the healthy baseline.
    pub margin_frac: f64,
    /// Consecutive bad windows required before drift is declared.
    pub patience: usize,
    baseline_margin: Option<f64>,
    consecutive_bad: usize,
}

impl DriftDetector {
    pub fn new(accuracy_floor: f64, patience: usize) -> Self {
        DriftDetector {
            accuracy_floor,
            margin_frac: 0.5,
            patience: patience.max(1),
            baseline_margin: None,
            consecutive_bad: 0,
        }
    }

    /// Feed one window; true when drift is sustained.
    pub fn push(&mut self, accuracy: Option<f64>, mean_margin: f64) -> bool {
        let margin_bad = self
            .baseline_margin
            .map(|b| mean_margin < self.margin_frac * b)
            .unwrap_or(false);
        let bad = match accuracy {
            Some(a) => a < self.accuracy_floor || margin_bad,
            None => margin_bad,
        };
        if bad {
            self.consecutive_bad += 1;
        } else {
            self.consecutive_bad = 0;
            // Healthy window: update the margin baseline (EWMA).
            self.baseline_margin = Some(match self.baseline_margin {
                None => mean_margin,
                Some(b) => 0.75 * b + 0.25 * mean_margin,
            });
        }
        self.consecutive_bad >= self.patience
    }

    /// Forget the bad streak (after a retune resolved it) but keep the
    /// learned margin baseline.
    pub fn reset(&mut self) {
        self.consecutive_bad = 0;
    }

    /// Forget the streak AND the learned margin baseline.  Required
    /// after an accepted swap to a different shape: the new model's
    /// healthy margins can be structurally smaller than the old
    /// model's, and a stale baseline would flag every window as
    /// collapsed — a perpetual retune storm.  The baseline re-forms
    /// from the next healthy windows.
    pub fn rebaseline(&mut self) {
        self.consecutive_bad = 0;
        self.baseline_margin = None;
    }

    pub fn consecutive_bad(&self) -> usize {
        self.consecutive_bad
    }
}

/// Produces the replacement model once drift is confirmed.  The default
/// is [`BudgetSearchTrainer`]; tests inject fixed/bad trainers to drive
/// the rollback and budget-gate paths deterministically.
pub trait ShadowTrainer: Send + Sync {
    fn retrain(&self, train: &Dataset, valid: &Dataset) -> BudgetedSearch;
}

/// The default shadow trainer: [`budget_search`] over
/// [`SearchSpace::around`] the deployed shape.
pub struct BudgetSearchTrainer {
    pub shape: TMShape,
    pub budget: ResourceBudget,
    pub epochs: usize,
    pub seed: u64,
}

impl ShadowTrainer for BudgetSearchTrainer {
    fn retrain(&self, train: &Dataset, valid: &Dataset) -> BudgetedSearch {
        let mut space = SearchSpace::around(&self.shape);
        space.epochs = self.epochs;
        space.seed = self.seed;
        budget_search(&self.shape, train, valid, &space, &self.budget)
    }
}

/// Autotuner policy knobs.
#[derive(Debug, Clone)]
pub struct AutotuneConfig {
    /// Windowed labeled accuracy below this marks a window bad.
    pub accuracy_floor: f64,
    /// Consecutive bad windows before drift is declared (hysteresis).
    pub patience: usize,
    /// Margin-collapse fraction vs. the healthy baseline.
    pub margin_frac: f64,
    /// Resource frontier a swapped-in model must fit.
    pub budget: ResourceBudget,
    /// Shadow-retrain epochs / PRNG seed (deterministic).
    pub epochs: usize,
    pub seed: u64,
    /// Post-swap windows averaged before the swap is judged.
    pub validation_windows: usize,
    /// The swap is kept if mean post-swap accuracy beats the
    /// trigger-time accuracy by at least this much, OR simply reaches
    /// `accuracy_floor` (a margin-triggered retune can fire at high
    /// labeled accuracy, where "trigger + gain" would be unreachable);
    /// otherwise the previous model is restored.
    pub min_gain: f64,
    /// Run the shadow search on a background thread (live mode).  When
    /// false the search runs inline in `observe_window` — the
    /// deterministic mode unit tests and the offline wrapper use.
    pub background: bool,
    /// Most-recent labeled samples retained as the retrain corpus.
    pub retrain_corpus: usize,
    /// Fraction of each observed window mirrored to the canary while a
    /// candidate is under evaluation.  `0.0` disables the canary gate
    /// entirely (candidates swap directly — the pre-canary behavior);
    /// pools with fewer than 2 live replicas fall back to the direct
    /// swap regardless.
    pub canary_fraction: f64,
    /// Paired canary windows before a unanimous early verdict.
    pub canary_min_windows: usize,
    /// Forced majority verdict at this many paired windows.
    pub canary_max_windows: usize,
    /// Label-free canary win rule: candidate mean margin/T must reach
    /// this fraction of the baseline's.
    pub canary_margin_frac: f64,
    /// Labeled canary win rule: candidate accuracy within this of the
    /// baseline's (or better).
    pub canary_accuracy_eps: f64,
    /// Sustained drift with fewer labeled corpus samples than this does
    /// not launch a retrain (a label-free deployment may have nothing
    /// to train on until labels are backfilled).
    pub min_corpus: usize,
    /// Unlabeled windows kept around (rows + predictions) for delayed
    /// label backfill; older windows age out.
    pub label_backfill_horizon: usize,
    /// Route labeled windows (live or backfilled) into the pool's
    /// online trainer FIRST when drift is sustained, instead of going
    /// straight to the shadow shape search.  A feedback mini-fence
    /// costs one TA-state sweep and one broadcast; a `budget_search`
    /// costs a full grid of retrains — most drift is distributional,
    /// not structural, and recovers from the cheap path.  Requires the
    /// handle's route to be enabled ([`ServiceHandle::enable_online_feedback`];
    /// [`Autotuner::install`] does this automatically).
    pub online_feedback: bool,
    /// Labeled feedback windows tolerated while the detector stays bad
    /// before escalating to the full shape search.
    pub online_patience: usize,
}

impl AutotuneConfig {
    pub fn new(budget: ResourceBudget) -> Self {
        AutotuneConfig {
            accuracy_floor: 0.85,
            patience: 2,
            margin_frac: 0.5,
            budget,
            epochs: 3,
            seed: 17,
            validation_windows: 1,
            min_gain: 0.05,
            background: true,
            retrain_corpus: 1024,
            canary_fraction: 0.25,
            canary_min_windows: 2,
            canary_max_windows: 6,
            canary_margin_frac: 0.9,
            canary_accuracy_eps: 0.02,
            min_corpus: 64,
            label_backfill_horizon: 8,
            online_feedback: false,
            online_patience: 3,
        }
    }
}

/// Decision log of one autotuned deployment.
#[derive(Debug, Clone)]
pub enum AutotuneEvent {
    /// Sustained drift confirmed (accuracy is None on a label-free
    /// trigger — margins alone declared it).
    DriftDetected { window: usize, accuracy: Option<f64>, mean_margin: f64 },
    /// Drift confirmed but the labeled corpus is below
    /// [`AutotuneConfig::min_corpus`]: no retrain launched.  Backfilled
    /// labels grow the corpus; the detector re-arms.
    RetrainStarved { window: usize, corpus: usize },
    SearchCompleted { window: usize, trials: usize, admitted: usize },
    /// The search's winner (or an injected trainer's output) failed the
    /// budget gate at swap time and was NOT programmed.
    BudgetRejected { window: usize, luts: u32, brams: u32, watts: f64 },
    /// No candidate fit the budget; the pool keeps the old model.
    NoCandidateFitsBudget { window: usize },
    /// The shadow-search thread died; monitoring resumes.
    SearchFailed { window: usize },
    /// A swap could not be carried through: the pool rejected the
    /// broadcast (e.g. the candidate overflows the replicas' ACTUAL
    /// memory depths — the budget costs the fitted deployment, not the
    /// pool's spec; the previously serving model was re-programmed, so
    /// the outage is one fence, never permanent), or a regression was
    /// detected with no recorded previous model to roll back to.
    SwapFailed { window: usize, error: String },
    /// The candidate was staged on one replica; live traffic routes
    /// away from it while the mirror evaluates.
    CanaryStarted { window: usize, replica: usize, version: u64 },
    /// The sequential comparison rejected the candidate: the lone
    /// canary was reprogrammed back.  No other replica ever served it.
    CanaryRejected { window: usize, evaluated: usize },
    /// The sequential comparison promoted the candidate; a `Swapped`
    /// event follows with the fleet broadcast's version.
    CanaryPromoted { window: usize, evaluated: usize },
    /// Delayed labels arrived for a past unlabeled window; its recorded
    /// accuracy was backfilled (the drift detector is NOT re-run on
    /// backfill).
    LabelsBackfilled { window: usize, accuracy: f64 },
    /// One labeled window was folded into the pool's online trainer;
    /// the updated model was broadcast behind the fence at `version`.
    OnlineFeedback { window: usize, version: u64, samples: usize },
    /// Online feedback alone cleared the sustained drift after
    /// `fed_windows` feedback windows — no shape search ran.
    OnlineRecovered { window: usize, fed_windows: usize },
    /// The detector stayed bad through `fed_windows` feedback windows:
    /// escalating to the full budget-constrained shape search.
    OnlineEscalated { window: usize, fed_windows: usize },
    Swapped {
        window: usize,
        version: u64,
        /// Trigger-time labeled accuracy (None on a label-free trigger).
        trigger_accuracy: Option<f64>,
        instructions: usize,
        luts: u32,
        brams: u32,
        watts: f64,
    },
    /// Post-swap validation accepted the model.  `mean_accuracy` is NaN
    /// when every validation window was unlabeled (the canary verdict
    /// already judged the candidate on live mirrors).
    Accepted { window: usize, mean_accuracy: f64 },
    RolledBack { window: usize, mean_accuracy: f64, version: u64 },
}

/// One resolved canary evaluation: when it started, when and how it
/// resolved, and every paired baseline-vs-candidate window.
#[derive(Debug, Clone)]
pub struct CanaryOutcome {
    pub started_window: usize,
    pub resolved_window: usize,
    pub verdict: CanaryVerdict,
    pub windows: Vec<PairedWindow>,
}

/// Telemetry + decisions of one autotuned deployment.
#[derive(Debug, Clone, Default)]
pub struct AutotuneReport {
    pub windows: Vec<WindowStats>,
    pub events: Vec<AutotuneEvent>,
    /// Every resolved canary evaluation, in order.
    pub canaries: Vec<CanaryOutcome>,
}

impl AutotuneReport {
    /// Serialize the full deployment record — monitoring windows,
    /// decision events, canary outcomes — as a self-contained JSON
    /// document (`rttm serve --autotune --report-json PATH`; schema in
    /// EXPERIMENTS.md §Canary).  Hand-rolled: no serde in the offline
    /// vendor set.  Missing accuracies serialize as `null`.
    pub fn to_json(&self) -> String {
        let mut s = String::from("{\n  \"windows\": [\n");
        for (i, w) in self.windows.iter().enumerate() {
            s.push_str(&format!(
                "    {{\"window\": {}, \"accuracy\": {}, \"mean_margin\": {}, \
                 \"samples\": {}, \"model_version\": {}}}{}\n",
                i,
                json_opt(w.accuracy),
                json_num(w.mean_margin),
                w.samples,
                w.model_version,
                comma(i, self.windows.len()),
            ));
        }
        s.push_str("  ],\n  \"events\": [\n");
        for (i, e) in self.events.iter().enumerate() {
            s.push_str("    ");
            s.push_str(&event_json(e));
            s.push_str(comma(i, self.events.len()));
            s.push('\n');
        }
        s.push_str("  ],\n  \"canaries\": [\n");
        for (i, c) in self.canaries.iter().enumerate() {
            s.push_str(&format!(
                "    {{\"started_window\": {}, \"resolved_window\": {}, \"verdict\": \"{}\", \
                 \"windows\": [",
                c.started_window,
                c.resolved_window,
                c.verdict.as_str(),
            ));
            for (j, w) in c.windows.iter().enumerate() {
                s.push_str(&format!(
                    "{{\"samples\": {}, \"baseline_margin\": {}, \"candidate_margin\": {}, \
                     \"baseline_accuracy\": {}, \"candidate_accuracy\": {}, \
                     \"agreement\": {}, \"candidate_wins\": {}}}{}",
                    w.samples,
                    json_num(w.baseline_margin),
                    json_num(w.candidate_margin),
                    json_opt(w.baseline_accuracy),
                    json_opt(w.candidate_accuracy),
                    json_num(w.agreement),
                    w.candidate_wins,
                    comma(j, c.windows.len()),
                ));
            }
            s.push_str(&format!("]}}{}\n", comma(i, self.canaries.len())));
        }
        s.push_str("  ]\n}\n");
        s
    }
}

fn comma(i: usize, len: usize) -> &'static str {
    if i + 1 == len {
        ""
    } else {
        ","
    }
}

/// A finite f64 as a JSON number; NaN/inf as null.
fn json_num(v: f64) -> String {
    if v.is_finite() {
        format!("{v:.6}")
    } else {
        "null".into()
    }
}

fn json_opt(v: Option<f64>) -> String {
    v.map(json_num).unwrap_or_else(|| "null".into())
}

/// Minimal JSON string escaping (quotes, backslashes, control chars) —
/// only `SwapFailed.error` carries free text.
fn json_str(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

fn event_json(e: &AutotuneEvent) -> String {
    match e {
        AutotuneEvent::DriftDetected { window, accuracy, mean_margin } => format!(
            "{{\"type\": \"drift_detected\", \"window\": {window}, \"accuracy\": {}, \
             \"mean_margin\": {}}}",
            json_opt(*accuracy),
            json_num(*mean_margin)
        ),
        AutotuneEvent::RetrainStarved { window, corpus } => format!(
            "{{\"type\": \"retrain_starved\", \"window\": {window}, \"corpus\": {corpus}}}"
        ),
        AutotuneEvent::SearchCompleted { window, trials, admitted } => format!(
            "{{\"type\": \"search_completed\", \"window\": {window}, \"trials\": {trials}, \
             \"admitted\": {admitted}}}"
        ),
        AutotuneEvent::BudgetRejected { window, luts, brams, watts } => format!(
            "{{\"type\": \"budget_rejected\", \"window\": {window}, \"luts\": {luts}, \
             \"brams\": {brams}, \"watts\": {}}}",
            json_num(*watts)
        ),
        AutotuneEvent::NoCandidateFitsBudget { window } => {
            format!("{{\"type\": \"no_candidate_fits_budget\", \"window\": {window}}}")
        }
        AutotuneEvent::SearchFailed { window } => {
            format!("{{\"type\": \"search_failed\", \"window\": {window}}}")
        }
        AutotuneEvent::SwapFailed { window, error } => format!(
            "{{\"type\": \"swap_failed\", \"window\": {window}, \"error\": {}}}",
            json_str(error)
        ),
        AutotuneEvent::CanaryStarted { window, replica, version } => format!(
            "{{\"type\": \"canary_started\", \"window\": {window}, \"replica\": {replica}, \
             \"version\": {version}}}"
        ),
        AutotuneEvent::CanaryRejected { window, evaluated } => format!(
            "{{\"type\": \"canary_rejected\", \"window\": {window}, \"evaluated\": {evaluated}}}"
        ),
        AutotuneEvent::CanaryPromoted { window, evaluated } => format!(
            "{{\"type\": \"canary_promoted\", \"window\": {window}, \"evaluated\": {evaluated}}}"
        ),
        AutotuneEvent::LabelsBackfilled { window, accuracy } => format!(
            "{{\"type\": \"labels_backfilled\", \"window\": {window}, \"accuracy\": {}}}",
            json_num(*accuracy)
        ),
        AutotuneEvent::OnlineFeedback { window, version, samples } => format!(
            "{{\"type\": \"online_feedback\", \"window\": {window}, \"version\": {version}, \
             \"samples\": {samples}}}"
        ),
        AutotuneEvent::OnlineRecovered { window, fed_windows } => format!(
            "{{\"type\": \"online_recovered\", \"window\": {window}, \
             \"fed_windows\": {fed_windows}}}"
        ),
        AutotuneEvent::OnlineEscalated { window, fed_windows } => format!(
            "{{\"type\": \"online_escalated\", \"window\": {window}, \
             \"fed_windows\": {fed_windows}}}"
        ),
        AutotuneEvent::Swapped {
            window,
            version,
            trigger_accuracy,
            instructions,
            luts,
            brams,
            watts,
        } => format!(
            "{{\"type\": \"swapped\", \"window\": {window}, \"version\": {version}, \
             \"trigger_accuracy\": {}, \"instructions\": {instructions}, \"luts\": {luts}, \
             \"brams\": {brams}, \"watts\": {}}}",
            json_opt(*trigger_accuracy),
            json_num(*watts)
        ),
        AutotuneEvent::Accepted { window, mean_accuracy } => format!(
            "{{\"type\": \"accepted\", \"window\": {window}, \"mean_accuracy\": {}}}",
            json_num(*mean_accuracy)
        ),
        AutotuneEvent::RolledBack { window, mean_accuracy, version } => format!(
            "{{\"type\": \"rolled_back\", \"window\": {window}, \"mean_accuracy\": {}, \
             \"version\": {version}}}",
            json_num(*mean_accuracy)
        ),
    }
}

enum Phase {
    Monitoring,
    /// Sustained drift with online feedback enabled: labeled windows
    /// (live or backfilled) are folded into the pool's online trainer
    /// instead of launching a shape search.  `fed_windows` counts the
    /// feedback windows applied; the detector staying bad through
    /// [`AutotuneConfig::online_patience`] of them escalates to
    /// [`Phase::Searching`].
    FeedingBack {
        trigger_accuracy: Option<f64>,
        fed_windows: usize,
    },
    Searching {
        trigger_accuracy: Option<f64>,
    },
    /// A candidate is staged on one replica; paired mirror windows
    /// accumulate toward a verdict.  Carries the candidate and its
    /// costed estimate so promote can emit a complete `Swapped` event.
    Canarying {
        trigger_accuracy: Option<f64>,
        controller: CanaryController,
        candidate: Arc<TMModel>,
        started_window: usize,
        instructions: usize,
        luts: u32,
        brams: u32,
        watts: f64,
    },
    Validating {
        trigger_accuracy: Option<f64>,
        windows_left: usize,
        acc_sum: f64,
        n: usize,
    },
}

enum SearchPoll {
    Pending,
    Done(BudgetedSearch),
    Died,
}

/// An unlabeled window retained for delayed-label backfill: the rows
/// and the predictions the pool served for them.
struct PendingLabels {
    window: usize,
    xs: Vec<Vec<u8>>,
    preds: Vec<usize>,
}

/// The live autotuner.  Owns nothing but a [`ServiceHandle`]: every
/// probe and every swap goes through the serving pool's request path.
pub struct Autotuner {
    handle: ServiceHandle,
    shape: TMShape,
    cfg: AutotuneConfig,
    trainer: Arc<dyn ShadowTrainer>,
    detector: DriftDetector,
    phase: Phase,
    /// Rollback target: what the pool ran before the last swap.
    previous: Option<Arc<TMModel>>,
    current: Option<Arc<TMModel>>,
    pending: Option<mpsc::Receiver<BudgetedSearch>>,
    corpus_xs: Vec<Vec<u8>>,
    corpus_ys: Vec<usize>,
    /// Unlabeled windows awaiting delayed labels (bounded by
    /// `cfg.label_backfill_horizon`).
    pending_labels: Vec<PendingLabels>,
    window_index: usize,
    /// True when the default budget search is in use: an accepted swap
    /// then re-anchors the search around the NEW shape.  Injected
    /// trainers ([`Self::with_trainer`]) are never replaced.
    reanchor: bool,
    pub report: AutotuneReport,
}

impl Autotuner {
    /// Autotuner with the default budget-constrained shadow search
    /// around `shape`.
    pub fn new(handle: ServiceHandle, shape: TMShape, cfg: AutotuneConfig) -> Self {
        let trainer = Arc::new(BudgetSearchTrainer {
            shape: shape.clone(),
            budget: cfg.budget.clone(),
            epochs: cfg.epochs,
            seed: cfg.seed,
        });
        let mut tuner = Self::with_trainer(handle, shape, cfg, trainer);
        tuner.reanchor = true;
        tuner
    }

    /// Autotuner with an injected shadow trainer (tests, custom search
    /// strategies).  The budget gate still applies at swap time; the
    /// injected trainer is kept across swaps (no re-anchoring).
    pub fn with_trainer(
        handle: ServiceHandle,
        shape: TMShape,
        cfg: AutotuneConfig,
        trainer: Arc<dyn ShadowTrainer>,
    ) -> Self {
        let detector = DriftDetector {
            margin_frac: cfg.margin_frac,
            ..DriftDetector::new(cfg.accuracy_floor, cfg.patience)
        };
        Autotuner {
            handle,
            shape,
            cfg,
            trainer,
            detector,
            phase: Phase::Monitoring,
            previous: None,
            current: None,
            pending: None,
            corpus_xs: Vec::new(),
            corpus_ys: Vec::new(),
            pending_labels: Vec::new(),
            window_index: 0,
            reanchor: false,
            report: AutotuneReport::default(),
        }
    }

    /// Program the initial model (recorded as the first rollback
    /// baseline).  With [`AutotuneConfig::online_feedback`] set this
    /// also opts the route into online feedback, warm-starting the
    /// pool's trainer from the installed model.
    pub fn install(&mut self, model: TMModel) -> Result<(), ServeError> {
        let m = Arc::new(model);
        self.handle.program((*m).clone())?;
        if self.cfg.online_feedback {
            self.handle.enable_online_feedback(self.cfg.seed)?;
        }
        self.current = Some(m);
        Ok(())
    }

    /// Model the autotuner believes the pool is serving.
    pub fn current_model(&self) -> Option<&TMModel> {
        self.current.as_deref()
    }

    pub fn is_searching(&self) -> bool {
        matches!(self.phase, Phase::Searching { .. })
    }

    pub fn phase_name(&self) -> &'static str {
        match self.phase {
            Phase::Monitoring => "monitoring",
            Phase::FeedingBack { .. } => "feeding_back",
            Phase::Searching { .. } => "searching",
            Phase::Canarying { .. } => "canarying",
            Phase::Validating { .. } => "validating",
        }
    }

    /// Feed one labeled monitoring window.  The probe goes through the
    /// serving pool (it IS traffic); the state machine then advances:
    /// detect → (shadow search) → canary → promote/reject →
    /// validate/rollback.
    pub fn observe_window(
        &mut self,
        xs: &[Vec<u8>],
        ys: &[usize],
    ) -> Result<WindowStats, ServeError> {
        self.observe(xs, Some(ys))
    }

    /// Feed one UNLABELED monitoring window — the fully label-free
    /// mode: drift is judged on confidence margins alone, and the
    /// window's rows + predictions are retained (bounded) so
    /// [`Self::backfill_labels`] can fill accuracy in when delayed
    /// labels arrive.
    pub fn observe_unlabeled(&mut self, xs: &[Vec<u8>]) -> Result<WindowStats, ServeError> {
        self.observe(xs, None)
    }

    fn observe(&mut self, xs: &[Vec<u8>], ys: Option<&[usize]>) -> Result<WindowStats, ServeError> {
        // A row/label mismatch would silently skew accuracy AND shift
        // every later corpus label against its sample — reject it
        // before anything is recorded.
        if let Some(ys) = ys {
            if xs.len() != ys.len() {
                return Err(ServeError::Core(crate::accel::core::CoreError::BadBatch {
                    rows: xs.len(),
                    reason: "window labels do not match rows",
                }));
            }
        }
        // Monitor probes are control traffic: at `High` class they keep
        // flowing — and drift detection keeps working — while bulk
        // `Low`/`Normal` traffic queues or sheds under overload.
        let tel = self
            .handle
            .infer_telemetry_class(xs.to_vec(), super::admission::Priority::High)?;
        let accuracy = ys.map(|ys| {
            tel.preds.iter().zip(ys).filter(|(p, y)| p == y).count() as f64
                / xs.len().max(1) as f64
        });
        let mean_margin = tel.margins.iter().map(|&m| m as f64).sum::<f64>()
            / tel.margins.len().max(1) as f64;
        let stats = WindowStats {
            accuracy,
            mean_margin,
            samples: xs.len(),
            model_version: tel.model_version,
        };
        self.report.windows.push(stats.clone());

        match ys {
            // Retrain corpus: most recent labeled samples, capped.
            Some(ys) => {
                self.corpus_xs.extend_from_slice(xs);
                self.corpus_ys.extend_from_slice(ys);
                self.cap_corpus();
            }
            // Unlabeled: retain rows + predictions for delayed backfill.
            None => {
                self.pending_labels.push(PendingLabels {
                    window: self.window_index,
                    xs: xs.to_vec(),
                    preds: tel.preds.clone(),
                });
                let horizon = self.cfg.label_backfill_horizon.max(1);
                if self.pending_labels.len() > horizon {
                    let drop = self.pending_labels.len() - horizon;
                    self.pending_labels.drain(..drop);
                }
            }
        }

        // Advance the window index even when the policy step fails: the
        // window WAS recorded (report.windows, pending_labels key), and
        // a stalled index would make the next window reuse this one's
        // id — misattributing backfills and event window ids.
        let stepped = self.step(accuracy, mean_margin, &tel, xs, ys);
        self.window_index += 1;
        stepped?;
        Ok(stats)
    }

    /// Delayed labels arrived for past unlabeled window `window`:
    /// backfill its accuracy into [`AutotuneReport::windows`], add the
    /// now-labeled samples to the retrain corpus, and record a
    /// `LabelsBackfilled` event.  The drift detector is deliberately
    /// NOT re-run — backfilled accuracy describes the past, and
    /// re-triggering on it would retune against a state the pool may
    /// have already left.  Returns the backfilled accuracy, or `None`
    /// when the window is unknown / already aged out of the horizon.
    pub fn backfill_labels(
        &mut self,
        window: usize,
        ys: &[usize],
    ) -> Result<Option<f64>, ServeError> {
        let Some(pos) = self.pending_labels.iter().position(|p| p.window == window) else {
            return Ok(None);
        };
        if ys.len() != self.pending_labels[pos].xs.len() {
            return Err(ServeError::Core(crate::accel::core::CoreError::BadBatch {
                rows: self.pending_labels[pos].xs.len(),
                reason: "backfill labels do not match window rows",
            }));
        }
        let p = self.pending_labels.remove(pos);
        let correct = p.preds.iter().zip(ys).filter(|(a, b)| a == b).count();
        let accuracy = correct as f64 / p.preds.len().max(1) as f64;
        self.report.windows[p.window].accuracy = Some(accuracy);
        // Late labels still feed the retrain corpus: a label-free
        // trigger needs SOMETHING to retrain on.
        self.corpus_xs.extend_from_slice(&p.xs);
        self.corpus_ys.extend_from_slice(ys);
        self.cap_corpus();
        self.report.events.push(AutotuneEvent::LabelsBackfilled {
            window: p.window,
            accuracy,
        });
        // A backfilled window IS a feedback window: while the tuner is
        // in the cheap recovery path, fold it into the online trainer
        // — this is how a delayed-label deployment recovers without a
        // single shape search.
        if matches!(self.phase, Phase::FeedingBack { .. }) {
            self.feed_online(&p.xs, ys)?;
            if let Phase::FeedingBack { fed_windows, .. } = &mut self.phase {
                *fed_windows += 1;
            }
        }
        Ok(Some(accuracy))
    }

    fn cap_corpus(&mut self) {
        let cap = self.cfg.retrain_corpus.max(1);
        if self.corpus_xs.len() > cap {
            let drop = self.corpus_xs.len() - cap;
            self.corpus_xs.drain(..drop);
            self.corpus_ys.drain(..drop);
        }
    }

    /// Block until a pending shadow search finishes and act on it.
    /// Returns true if a search was pending.  Serving traffic continues
    /// on the pool the whole time — only the policy thread waits.
    pub fn finish_pending_search(&mut self) -> Result<bool, ServeError> {
        let trigger_accuracy = match &self.phase {
            Phase::Searching { trigger_accuracy } => *trigger_accuracy,
            _ => return Ok(false),
        };
        match self.poll_search(true) {
            SearchPoll::Done(outcome) => {
                self.finish_search(outcome, trigger_accuracy)?;
                Ok(true)
            }
            SearchPoll::Died => {
                self.search_died();
                Ok(true)
            }
            SearchPoll::Pending => unreachable!("blocking poll never returns Pending"),
        }
    }

    fn step(
        &mut self,
        accuracy: Option<f64>,
        mean_margin: f64,
        tel: &Telemetry,
        xs: &[Vec<u8>],
        ys: Option<&[usize]>,
    ) -> Result<(), ServeError> {
        // Take the phase out; every arm either leaves the default
        // (Monitoring) or writes the successor phase back.
        match std::mem::replace(&mut self.phase, Phase::Monitoring) {
            Phase::Monitoring => {
                if self.detector.push(accuracy, mean_margin) {
                    self.report.events.push(AutotuneEvent::DriftDetected {
                        window: self.window_index,
                        accuracy,
                        mean_margin,
                    });
                    if self.cfg.online_feedback {
                        // Cheap recovery path first: fine-tune the
                        // serving model in place with labeled windows.
                        // The triggering window's own labels (if any)
                        // are the first feedback window.
                        let mut fed_windows = 0;
                        if let Some(ys) = ys {
                            self.feed_online(xs, ys)?;
                            fed_windows = 1;
                        }
                        self.phase = Phase::FeedingBack {
                            trigger_accuracy: accuracy,
                            fed_windows,
                        };
                    } else if self.corpus_xs.len() < self.cfg.min_corpus.max(2) {
                        // Label-free deployment with nothing to retrain
                        // on yet: record the starvation, re-arm the
                        // detector, wait for backfilled labels.
                        self.report.events.push(AutotuneEvent::RetrainStarved {
                            window: self.window_index,
                            corpus: self.corpus_xs.len(),
                        });
                        self.detector.reset();
                    } else {
                        self.launch_search(accuracy)?;
                    }
                }
            }
            Phase::FeedingBack { trigger_accuracy, mut fed_windows } => {
                // Judge THIS window first — it was served by the
                // already-fed model, so its accuracy/margin is the
                // recovery evidence.
                self.detector.push(accuracy, mean_margin);
                if self.detector.consecutive_bad() == 0 {
                    // A healthy window ends the episode: the drift was
                    // distributional and the cheap path fixed it.  No
                    // rebaseline — the shape did not change, and the
                    // margin EWMA already updated on the good window.
                    self.report.events.push(AutotuneEvent::OnlineRecovered {
                        window: self.window_index,
                        fed_windows,
                    });
                    return Ok(());
                }
                if let Some(ys) = ys {
                    self.feed_online(xs, ys)?;
                    fed_windows += 1;
                }
                // No-labels escape hatch: a bad streak that outlives
                // the backfill horizon with zero feedback applied means
                // labels are not coming (the pending windows have aged
                // out) — the cheap path can never act, so escalate.
                let starved_of_labels = fed_windows == 0
                    && self.detector.consecutive_bad()
                        >= self.cfg.patience.max(1) + self.cfg.label_backfill_horizon.max(1);
                if fed_windows >= self.cfg.online_patience.max(1) || starved_of_labels {
                    // The detector stayed bad through the patience
                    // budget: the drift is structural — escalate to the
                    // full shape search.
                    self.report.events.push(AutotuneEvent::OnlineEscalated {
                        window: self.window_index,
                        fed_windows,
                    });
                    if self.corpus_xs.len() < self.cfg.min_corpus.max(2) {
                        self.report.events.push(AutotuneEvent::RetrainStarved {
                            window: self.window_index,
                            corpus: self.corpus_xs.len(),
                        });
                        self.detector.reset();
                    } else {
                        self.launch_search(trigger_accuracy)?;
                    }
                } else {
                    self.phase = Phase::FeedingBack { trigger_accuracy, fed_windows };
                }
            }
            Phase::Searching { trigger_accuracy } => {
                self.phase = Phase::Searching { trigger_accuracy };
                match self.poll_search(false) {
                    SearchPoll::Pending => {}
                    SearchPoll::Done(outcome) => self.finish_search(outcome, trigger_accuracy)?,
                    SearchPoll::Died => self.search_died(),
                }
            }
            Phase::Canarying {
                trigger_accuracy,
                mut controller,
                candidate,
                started_window,
                instructions,
                luts,
                brams,
                watts,
            } => {
                // The monitor telemetry above already answered the FULL
                // window on a baseline replica; reuse its hash-sampled
                // half so the mirror costs one canary round-trip, not
                // two pool round-trips.
                // Extend and a transient request error (e.g. a replica
                // panicked mid-mirror and was respawned) both keep the
                // evaluation alive — one shared phase-restore site.  A
                // vanished canary (ServeError::Canary: its replica died
                // and DeathWatch dismissed it, or an external broadcast
                // replaced the pool model) aborts the evaluation
                // instead: restoring the phase would wedge the tuner on
                // that error forever, and the pool is already healthy.
                let mut keep_going = Ok(());
                let verdict = match controller.observe_with_baseline(xs, ys, tel) {
                    Ok((_paired, CanaryVerdict::Extend)) => None,
                    Ok((_paired, verdict)) => Some(verdict),
                    Err(ServeError::Canary(reason)) => {
                        self.abort_canary(started_window, controller, reason);
                        return Ok(());
                    }
                    Err(e) => {
                        keep_going = Err(e);
                        None
                    }
                };
                let Some(verdict) = verdict else {
                    self.phase = Phase::Canarying {
                        trigger_accuracy,
                        controller,
                        candidate,
                        started_window,
                        instructions,
                        luts,
                        brams,
                        watts,
                    };
                    return keep_going;
                };
                let windows = controller.into_windows();
                let evaluated = windows.len();
                match verdict {
                    CanaryVerdict::Extend => unreachable!("handled above"),
                    CanaryVerdict::Reject => {
                        // Record the concluded evaluation BEFORE the
                        // dismissal fence: a dismissal error must not
                        // erase a verdict that was actually reached.
                        self.report.events.push(AutotuneEvent::CanaryRejected {
                            window: self.window_index,
                            evaluated,
                        });
                        self.report.canaries.push(CanaryOutcome {
                            started_window,
                            resolved_window: self.window_index,
                            verdict: CanaryVerdict::Reject,
                            windows,
                        });
                        self.detector.reset();
                        // The candidate loses: reprogram the lone canary
                        // back.  No other replica ever served it, and
                        // live traffic never saw it at all.
                        self.handle.dismiss_canary()?;
                    }
                    CanaryVerdict::Promote => {
                        if let Err(e) = self.handle.promote_canary() {
                            // The broadcast failed mid-promote: replicas
                            // may be unprogrammed — restore the serving
                            // model immediately (it fit a moment ago).
                            if let Some(cur) = self.current.clone() {
                                self.handle.program((*cur).clone())?;
                            }
                            self.report.events.push(AutotuneEvent::SwapFailed {
                                window: self.window_index,
                                error: e.to_string(),
                            });
                            // The verdict said promote but the fleet
                            // never received it: the evaluation is
                            // recorded UNRESOLVED (Extend), never as a
                            // promotion that did not happen.
                            self.report.canaries.push(CanaryOutcome {
                                started_window,
                                resolved_window: self.window_index,
                                verdict: CanaryVerdict::Extend,
                                windows,
                            });
                            self.detector.reset();
                        } else {
                            self.previous = self.current.clone();
                            self.current = Some(candidate);
                            self.report.events.push(AutotuneEvent::CanaryPromoted {
                                window: self.window_index,
                                evaluated,
                            });
                            self.report.events.push(AutotuneEvent::Swapped {
                                window: self.window_index,
                                version: self.handle.pool_stats().version,
                                trigger_accuracy,
                                instructions,
                                luts,
                                brams,
                                watts,
                            });
                            self.report.canaries.push(CanaryOutcome {
                                started_window,
                                resolved_window: self.window_index,
                                verdict: CanaryVerdict::Promote,
                                windows,
                            });
                            self.phase = Phase::Validating {
                                trigger_accuracy,
                                windows_left: self.cfg.validation_windows.max(1),
                                acc_sum: 0.0,
                                n: 0,
                            };
                        }
                    }
                }
            }
            Phase::Validating { trigger_accuracy, windows_left, acc_sum, n } => {
                // Unlabeled validation windows contribute nothing to the
                // mean; a fully unlabeled validation accepts (the canary
                // verdict already judged the candidate on live mirrors).
                let acc_sum = acc_sum + accuracy.unwrap_or(0.0);
                let n = n + usize::from(accuracy.is_some());
                if windows_left <= 1 {
                    if n == 0 {
                        self.accept_swap(f64::NAN);
                    } else {
                        let mean = acc_sum / n as f64;
                        // Healthy is good enough: a margin-triggered
                        // retune can have trigger accuracy near 1.0 (or
                        // none at all), where "trigger + gain" is
                        // unreachable and would doom every swap to
                        // rollback (a retrain-rollback loop).
                        let kept = mean >= self.cfg.accuracy_floor
                            || trigger_accuracy.is_some_and(|t| mean >= t + self.cfg.min_gain);
                        if !kept {
                            // The retrain did not help: restore the
                            // previous model (another fence-gated
                            // program — versions stay strictly
                            // monotone).
                            match self.previous.clone() {
                                Some(prev) => {
                                    self.handle.program((*prev).clone())?;
                                    self.current = Some(prev);
                                    self.report.events.push(AutotuneEvent::RolledBack {
                                        window: self.window_index,
                                        mean_accuracy: mean,
                                        version: self.handle.pool_stats().version,
                                    });
                                }
                                // Nothing to restore (the pool was
                                // programmed behind the tuner's back):
                                // record honestly — the regressing model
                                // keeps serving, NOT a phantom rollback.
                                None => self.report.events.push(AutotuneEvent::SwapFailed {
                                    window: self.window_index,
                                    error: format!(
                                        "regression (mean accuracy {mean:.3}) with no \
                                         previous model to roll back to"
                                    ),
                                }),
                            }
                            // The old model is back (or was never
                            // recorded): the margin baseline stays, only
                            // the streak clears.
                            self.detector.reset();
                        } else {
                            self.accept_swap(mean);
                        }
                    }
                } else {
                    self.phase = Phase::Validating {
                        trigger_accuracy,
                        windows_left: windows_left - 1,
                        acc_sum,
                        n,
                    };
                }
            }
        }
        Ok(())
    }

    /// The canary vanished mid-evaluation (replica death, or an
    /// external broadcast dismissed it): record the evaluation as
    /// unresolved and resume monitoring.  The pool is already healthy —
    /// whatever cleared the canary also restored consistent serving.
    fn abort_canary(
        &mut self,
        started_window: usize,
        controller: CanaryController,
        reason: &'static str,
    ) {
        let windows = controller.into_windows();
        self.report.events.push(AutotuneEvent::SwapFailed {
            window: self.window_index,
            error: format!("canary evaluation aborted: {reason}"),
        });
        self.report.canaries.push(CanaryOutcome {
            started_window,
            resolved_window: self.window_index,
            // Extend = unresolved: no verdict was ever reached.
            verdict: CanaryVerdict::Extend,
            windows,
        });
        self.detector.reset();
    }

    /// Post-swap validation accepted the promoted model: log it,
    /// re-learn the margin baseline (the new shape's healthy margin
    /// scale may differ — a stale EWMA would flag every window as
    /// collapsed), and re-anchor the default shadow search to the
    /// accepted shape.
    fn accept_swap(&mut self, mean_accuracy: f64) {
        self.report.events.push(AutotuneEvent::Accepted {
            window: self.window_index,
            mean_accuracy,
        });
        self.detector.rebaseline();
        if self.reanchor {
            if let Some(cur) = &self.current {
                self.shape = cur.shape.clone();
                self.trainer = Arc::new(BudgetSearchTrainer {
                    shape: cur.shape.clone(),
                    budget: self.cfg.budget.clone(),
                    epochs: self.cfg.epochs,
                    seed: self.cfg.seed,
                });
            }
        }
    }

    fn corpus_dataset(&self) -> Dataset {
        let features = self.corpus_xs.first().map(|r| r.len()).unwrap_or(0);
        Dataset {
            xs: self.corpus_xs.clone(),
            ys: self.corpus_ys.clone(),
            spec: SynthSpec::new(features, self.shape.classes, self.corpus_xs.len()),
        }
    }

    /// Fold one labeled window into the pool's online trainer
    /// ([`ServiceHandle::feedback`]): one TA-state sweep on a replica,
    /// one fence-gated broadcast of the updated model.  `current` is
    /// deliberately NOT advanced — it stays the pre-drift rollback
    /// baseline, so an escalated search that regresses still restores
    /// a model that once served healthily.
    fn feed_online(&mut self, xs: &[Vec<u8>], ys: &[usize]) -> Result<(), ServeError> {
        self.handle.feedback(xs.to_vec(), ys.to_vec())?;
        self.report.events.push(AutotuneEvent::OnlineFeedback {
            window: self.window_index,
            version: self.handle.pool_stats().version,
            samples: xs.len(),
        });
        Ok(())
    }

    fn launch_search(&mut self, trigger_accuracy: Option<f64>) -> Result<(), ServeError> {
        let (train, valid) = self.corpus_dataset().split(0.75);
        self.phase = Phase::Searching { trigger_accuracy };
        if self.cfg.background {
            let trainer = Arc::clone(&self.trainer);
            let (tx, rx) = mpsc::channel();
            std::thread::Builder::new()
                .name("rttm-autotune-search".into())
                .spawn(move || {
                    let _ = tx.send(trainer.retrain(&train, &valid));
                })
                .expect("spawn shadow-search thread");
            self.pending = Some(rx);
        } else {
            let outcome = self.trainer.retrain(&train, &valid);
            self.finish_search(outcome, trigger_accuracy)?;
        }
        Ok(())
    }

    fn poll_search(&mut self, block: bool) -> SearchPoll {
        let Some(rx) = self.pending.as_ref() else {
            return SearchPoll::Died;
        };
        let polled = if block {
            rx.recv().map_err(|_| mpsc::TryRecvError::Disconnected)
        } else {
            rx.try_recv()
        };
        match polled {
            Ok(outcome) => {
                self.pending = None;
                SearchPoll::Done(outcome)
            }
            Err(mpsc::TryRecvError::Empty) => SearchPoll::Pending,
            Err(mpsc::TryRecvError::Disconnected) => {
                self.pending = None;
                SearchPoll::Died
            }
        }
    }

    fn search_died(&mut self) {
        self.report.events.push(AutotuneEvent::SearchFailed { window: self.window_index });
        self.detector.reset();
        self.phase = Phase::Monitoring;
    }

    fn finish_search(
        &mut self,
        outcome: BudgetedSearch,
        trigger_accuracy: Option<f64>,
    ) -> Result<(), ServeError> {
        let admitted = outcome.trials.iter().filter(|t| t.admitted).count();
        self.report.events.push(AutotuneEvent::SearchCompleted {
            window: self.window_index,
            trials: outcome.trials.len(),
            admitted,
        });
        let Some(model) = outcome.winner else {
            self.report.events.push(AutotuneEvent::NoCandidateFitsBudget {
                window: self.window_index,
            });
            self.detector.reset();
            self.phase = Phase::Monitoring;
            return Ok(());
        };
        // Budget gate at the swap, independent of how the model was
        // produced: trainers are pluggable, the frontier is not.  A
        // candidate exceeding the budget is never programmed.
        let deploy = fitted_config(&model);
        let est = estimate(&deploy);
        let watts = EnergyModel::for_config(&deploy).watts;
        if !self.cfg.budget.admits(&est, watts) {
            self.report.events.push(AutotuneEvent::BudgetRejected {
                window: self.window_index,
                luts: est.luts,
                brams: est.brams,
                watts,
            });
            self.detector.reset();
            self.phase = Phase::Monitoring;
            return Ok(());
        }
        let instructions = crate::isa::instruction_count(&model);
        let m = Arc::new(model);

        // The canary gate: stage the candidate on exactly one replica
        // and let paired mirror windows decide.  Pools that cannot
        // spare a replica (or a disabled gate) fall through to the
        // direct fence-gated swap below.
        if self.cfg.canary_fraction > 0.0 {
            match self.handle.program_canary((*m).clone()) {
                Ok(replica) => {
                    self.report.events.push(AutotuneEvent::CanaryStarted {
                        window: self.window_index,
                        replica,
                        version: self.handle.pool_stats().version,
                    });
                    let ccfg = CanaryConfig {
                        mirror_fraction: self.cfg.canary_fraction,
                        min_windows: self.cfg.canary_min_windows,
                        max_windows: self.cfg.canary_max_windows,
                        margin_frac: self.cfg.canary_margin_frac,
                        accuracy_eps: self.cfg.canary_accuracy_eps,
                        baseline_t: self.current.as_ref().map(|c| c.shape.t).unwrap_or(1),
                        candidate_t: m.shape.t,
                        ..CanaryConfig::default()
                    };
                    self.phase = Phase::Canarying {
                        trigger_accuracy,
                        controller: CanaryController::new(self.handle.clone(), ccfg),
                        candidate: m,
                        started_window: self.window_index,
                        instructions,
                        luts: est.luts,
                        brams: est.brams,
                        watts,
                    };
                    return Ok(());
                }
                // Too few replicas / no baseline: direct swap instead.
                Err(ServeError::Canary(_)) => {}
                Err(e) => {
                    // The canary program itself failed (e.g. the
                    // candidate overflows the replica's memories):
                    // restore the LONE disturbed replica and resume
                    // monitoring — the rest of the pool never stopped
                    // serving the old model.
                    self.handle.dismiss_canary()?;
                    self.report.events.push(AutotuneEvent::SwapFailed {
                        window: self.window_index,
                        error: e.to_string(),
                    });
                    self.detector.reset();
                    self.phase = Phase::Monitoring;
                    return Ok(());
                }
            }
        }

        if let Err(e) = self.handle.program((*m).clone()) {
            // The broadcast failed — a failed swap deliberately leaves
            // replicas UNPROGRAMMED (never stale), so the serving model
            // must be restored right here or the pool is a permanent
            // outage.  The restore re-programs what was serving a
            // moment ago, so it fits the replicas' memories.
            if let Some(cur) = self.current.clone() {
                self.handle.program((*cur).clone())?;
            }
            self.report.events.push(AutotuneEvent::SwapFailed {
                window: self.window_index,
                error: e.to_string(),
            });
            self.detector.reset();
            self.phase = Phase::Monitoring;
            return Ok(());
        }
        self.previous = self.current.clone();
        self.current = Some(m);
        self.report.events.push(AutotuneEvent::Swapped {
            window: self.window_index,
            version: self.handle.pool_stats().version,
            trigger_accuracy,
            instructions,
            luts: est.luts,
            brams: est.brams,
            watts,
        });
        self.phase = Phase::Validating {
            trigger_accuracy,
            windows_left: self.cfg.validation_windows.max(1),
            acc_sum: 0.0,
            n: 0,
        };
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::server::spawn_pool;
    use crate::coordinator::EngineSpec;
    use crate::datasets::synth::SynthSpec;
    use crate::TMShape;

    fn shape() -> TMShape {
        TMShape::synthetic(12, 3, 8)
    }

    fn dataset(drift: f64, n: usize, seed: u64) -> Dataset {
        SynthSpec::new(12, 3, n).noise(0.05).seed(seed).drift(drift).generate()
    }

    fn trained(data: &Dataset) -> TMModel {
        crate::trainer::train_model(&shape(), data, 4, 2)
    }

    // ---- hysteresis: pure DriftDetector state machine ----------------

    #[test]
    fn hysteresis_table_driven() {
        // (accuracy, margin, expect_triggered) with floor .8, patience 2.
        let cases: &[(&str, &[(f64, f64, bool)])] = &[
            (
                "single bad window never triggers",
                &[(0.95, 10.0, false), (0.40, 2.0, false), (0.95, 10.0, false)],
            ),
            (
                "two consecutive bad windows trigger",
                &[(0.95, 10.0, false), (0.40, 2.0, false), (0.42, 2.0, true)],
            ),
            (
                "non-consecutive bad windows never trigger",
                &[
                    (0.40, 2.0, false),
                    (0.95, 10.0, false),
                    (0.40, 2.0, false),
                    (0.95, 10.0, false),
                    (0.40, 2.0, false),
                ],
            ),
            (
                "healthy stream never triggers",
                &[(0.92, 9.0, false), (0.97, 11.0, false), (0.93, 10.0, false)],
            ),
        ];
        for (name, seq) in cases {
            let mut d = DriftDetector::new(0.8, 2);
            for (i, &(acc, margin, expect)) in seq.iter().enumerate() {
                assert_eq!(
                    d.push(Some(acc), margin),
                    expect,
                    "case {name:?}, window {i}"
                );
            }
        }
    }

    #[test]
    fn label_free_margin_only_triggering_table_driven() {
        // Fully unlabeled streams: every push is (None, margin).
        // margin_frac 0.5, patience 2.  Expected = index of the first
        // window that declares drift, or None.
        let cases: &[(&str, &[f64], Option<usize>)] = &[
            ("healthy margins never trigger", &[10.0, 9.5, 10.5, 9.8], None),
            ("sustained collapse triggers", &[10.0, 10.0, 2.0, 2.0], Some(3)),
            (
                "single collapsed windows never trigger",
                &[10.0, 2.0, 10.0, 2.0, 10.0],
                None,
            ),
            // With no baseline yet, collapse cannot be judged: the low
            // margins BECOME the baseline (a model that is natively
            // low-margin is not drifting).
            ("collapse before any baseline never triggers", &[2.0, 2.0, 2.0], None),
            (
                "recovery resets the streak",
                &[10.0, 10.0, 2.0, 9.9, 2.0, 10.1, 2.0],
                None,
            ),
        ];
        for (name, margins, expect) in cases {
            let mut d = DriftDetector::new(0.8, 2);
            let mut fired = None;
            for (i, &m) in margins.iter().enumerate() {
                if d.push(None, m) && fired.is_none() {
                    fired = Some(i);
                }
            }
            assert_eq!(fired, *expect, "case {name:?}");
        }
    }

    #[test]
    fn margin_collapse_triggers_without_labels() {
        let mut d = DriftDetector::new(0.8, 2);
        // Establish a healthy baseline margin ~10.
        assert!(!d.push(Some(0.95), 10.0));
        assert!(!d.push(Some(0.96), 10.0));
        // Unlabeled windows with collapsed margins must still trigger.
        assert!(!d.push(None, 2.0));
        assert!(d.push(None, 2.0));
        // And unlabeled windows with healthy margins must not.
        let mut d = DriftDetector::new(0.8, 2);
        assert!(!d.push(Some(0.95), 10.0));
        assert!(!d.push(None, 9.0));
        assert!(!d.push(None, 11.0));
        assert_eq!(d.consecutive_bad(), 0);
    }

    #[test]
    fn reset_clears_streak_not_baseline() {
        let mut d = DriftDetector::new(0.8, 3);
        assert!(!d.push(Some(0.9), 10.0));
        assert!(!d.push(Some(0.5), 2.0));
        assert!(!d.push(Some(0.5), 2.0));
        d.reset();
        assert_eq!(d.consecutive_bad(), 0);
        // Margin baseline survived: collapse still counts as bad.
        assert!(!d.push(None, 2.0));
        assert!(!d.push(None, 2.0));
        assert!(d.push(None, 2.0));
    }

    #[test]
    fn mismatched_window_labels_are_rejected_before_recording() {
        let clean = dataset(0.0, 64, 7);
        let good = trained(&clean);
        let mut cfg = AutotuneConfig::new(ResourceBudget::unlimited());
        cfg.background = false;
        let (mut tuner, mut join) = autotuner_on_pool(cfg, Arc::new(EmptySearchTrainer));
        tuner.install(good).unwrap();
        let short_ys = &clean.ys[..63];
        assert!(matches!(
            tuner.observe_window(&clean.xs, short_ys),
            Err(crate::coordinator::ServeError::Core(
                crate::accel::core::CoreError::BadBatch { rows: 64, .. }
            ))
        ));
        // Nothing was recorded: no window, no corpus desync.
        assert!(tuner.report.windows.is_empty());
        let ok = tuner.observe_window(&clean.xs, &clean.ys).unwrap();
        assert_eq!(ok.samples, 64);
        tuner.handle.shutdown();
        join.join();
    }

    #[test]
    fn rebaseline_forgets_margin_baseline() {
        let mut d = DriftDetector::new(0.8, 2);
        assert!(!d.push(Some(0.9), 20.0)); // baseline 20
        d.rebaseline();
        // Margins at half the OLD baseline are healthy, not collapsed:
        // no baseline exists until a new good window establishes one.
        assert!(!d.push(Some(0.9), 8.0));
        assert!(!d.push(Some(0.9), 8.0));
        assert_eq!(d.consecutive_bad(), 0);
        // The new baseline is the new scale: collapse is judged vs 8.
        assert!(!d.push(None, 3.0));
        assert!(d.push(None, 3.0));
    }

    // ---- injected trainers --------------------------------------------

    /// Returns a fixed model as the search winner (one synthetic trial).
    struct FixedTrainer(TMModel);

    impl ShadowTrainer for FixedTrainer {
        fn retrain(&self, _train: &Dataset, _valid: &Dataset) -> BudgetedSearch {
            let cfg = fitted_config(&self.0);
            let est = estimate(&cfg);
            let watts = EnergyModel::for_config(&cfg).watts;
            BudgetedSearch {
                trials: vec![crate::coordinator::hyperparam::BudgetedTrial {
                    t: self.0.shape.t,
                    s: self.0.shape.s,
                    clauses: self.0.shape.clauses,
                    accuracy: 0.0,
                    instructions: crate::isa::instruction_count(&self.0),
                    estimate: est,
                    watts,
                    model_bytes: crate::model_cost::resources::compressed_model_bytes(&self.0),
                    admitted: true,
                }],
                winner: Some(self.0.clone()),
            }
        }
    }

    fn autotuner_on_pool(
        cfg: AutotuneConfig,
        trainer: Arc<dyn ShadowTrainer>,
    ) -> (Autotuner, crate::coordinator::PoolJoin) {
        let (handle, join) = spawn_pool(EngineSpec::base(), 1);
        (Autotuner::with_trainer(handle, shape(), cfg, trainer), join)
    }

    // ---- rollback: injected bad retrain restores the old model --------

    #[test]
    fn rollback_restores_previous_model_with_monotone_versions() {
        let clean = dataset(0.0, 256, 7);
        let drifted = dataset(0.35, 256, 7);
        let good = trained(&clean);

        // The "retrained" model is untrained: tautology killers only,
        // predicts class 0 everywhere — guaranteed regression.
        let bad = TMModel::empty(shape());

        let mut cfg = AutotuneConfig::new(ResourceBudget::unlimited());
        cfg.patience = 2;
        cfg.accuracy_floor = 0.85;
        cfg.validation_windows = 1;
        cfg.min_gain = 0.4; // force the regression judgment
        cfg.background = false; // deterministic inline search
        let (mut tuner, mut join) = autotuner_on_pool(cfg, Arc::new(FixedTrainer(bad)));
        tuner.install(good.clone()).unwrap();

        let before = tuner.handle.infer(clean.xs.clone()).unwrap();

        // Healthy, then sustained drift (trigger), then one validation
        // window under the bad swap → rollback.
        tuner.observe_window(&clean.xs, &clean.ys).unwrap();
        tuner.observe_window(&drifted.xs, &drifted.ys).unwrap();
        tuner.observe_window(&drifted.xs, &drifted.ys).unwrap(); // trigger + swap
        tuner.observe_window(&drifted.xs, &drifted.ys).unwrap(); // validate → rollback

        let swapped = tuner
            .report
            .events
            .iter()
            .any(|e| matches!(e, AutotuneEvent::Swapped { .. }));
        let rolled = tuner
            .report
            .events
            .iter()
            .any(|e| matches!(e, AutotuneEvent::RolledBack { .. }));
        assert!(swapped, "bad model must first be swapped in: {:?}", tuner.report.events);
        assert!(rolled, "regressing swap must roll back: {:?}", tuner.report.events);

        // Previous model restored: same predictions as before the swap.
        let after = tuner.handle.infer(clean.xs.clone()).unwrap();
        assert_eq!(before, after);
        assert_eq!(tuner.current_model().unwrap(), &good);

        // Versions strictly monotone: install(1) → swap(2) → rollback(3).
        assert_eq!(tuner.handle.pool_stats().version, 3);
        tuner.handle.shutdown();
        join.join();
    }

    // ---- budget gate: over-budget candidate never programmed ----------

    #[test]
    fn over_budget_candidate_is_never_programmed() {
        let clean = dataset(0.0, 256, 7);
        let drifted = dataset(0.35, 256, 7);
        let good = trained(&clean);

        // Impossible LUT budget: whatever the trainer returns must be
        // rejected at the swap gate.
        let mut cfg = AutotuneConfig::new(ResourceBudget::unlimited().with_luts(1));
        cfg.patience = 2;
        cfg.validation_windows = 1;
        cfg.background = false;
        let candidate = trained(&drifted);
        let (mut tuner, mut join) = autotuner_on_pool(cfg, Arc::new(FixedTrainer(candidate)));
        tuner.install(good.clone()).unwrap();

        tuner.observe_window(&drifted.xs, &drifted.ys).unwrap();
        tuner.observe_window(&drifted.xs, &drifted.ys).unwrap(); // trigger

        assert!(tuner
            .report
            .events
            .iter()
            .any(|e| matches!(e, AutotuneEvent::BudgetRejected { .. })));
        assert!(!tuner
            .report
            .events
            .iter()
            .any(|e| matches!(e, AutotuneEvent::Swapped { .. })));
        // Only the install ever programmed the pool.
        assert_eq!(tuner.handle.pool_stats().version, 1);
        assert_eq!(tuner.current_model().unwrap(), &good);
        // Back to monitoring: the tuner is not wedged.
        assert_eq!(tuner.phase_name(), "monitoring");
        tuner.handle.shutdown();
        join.join();
    }

    // ---- failed swap broadcast restores the serving model -------------

    #[test]
    fn failed_swap_restores_the_serving_model() {
        use crate::accel::core::AccelConfig;

        let clean = dataset(0.0, 256, 7);
        let drifted = dataset(0.35, 256, 7);
        let good = trained(&clean);

        // Pool memories sized EXACTLY for the serving model; the
        // candidate is bigger, so the broadcast itself fails even
        // though an unlimited budget admits its fitted deployment.
        let n_small = crate::isa::instruction_count(&good);
        let big_shape = TMShape::synthetic(12, 3, 48);
        let big_data = SynthSpec::new(12, 3, 256).noise(0.05).seed(9).generate();
        let big = crate::trainer::train_model(&big_shape, &big_data, 4, 2);
        assert!(crate::isa::instruction_count(&big) > n_small, "test premise");

        let mut cfg = AutotuneConfig::new(ResourceBudget::unlimited());
        cfg.patience = 1;
        cfg.background = false;
        let spec = EngineSpec::custom(AccelConfig::base().with_depths(n_small, 2048));
        let (handle, mut join) = spawn_pool(spec, 2);
        let mut tuner = Autotuner::with_trainer(handle, shape(), cfg, Arc::new(FixedTrainer(big)));
        tuner.install(good.clone()).unwrap();
        let before = tuner.handle.infer(clean.xs.clone()).unwrap();

        // Trigger → the canary program fails (candidate too big for the
        // replica's memories) → the lone disturbed replica is restored.
        // Only ONE replica was ever touched by the doomed candidate.
        tuner.observe_window(&drifted.xs, &drifted.ys).unwrap();

        assert!(tuner
            .report
            .events
            .iter()
            .any(|e| matches!(e, AutotuneEvent::SwapFailed { .. })));
        // NOT a permanent outage: the pool still serves the old model.
        assert_eq!(tuner.handle.infer(clean.xs.clone()).unwrap(), before);
        assert_eq!(tuner.current_model().unwrap(), &good);
        assert_eq!(tuner.phase_name(), "monitoring");
        // install(1) + failed canary program(2) + dismissal(3): monotone.
        assert_eq!(tuner.handle.pool_stats().version, 3);
        tuner.handle.shutdown();
        join.join();
    }

    // ---- label-free deployment: margin triggers, backfill, starvation -

    #[test]
    fn label_free_windows_trigger_and_backfill_updates_without_retriggering() {
        let clean = dataset(0.0, 128, 7);
        let drifted = dataset(0.5, 128, 7);
        let good = trained(&clean);
        let mut cfg = AutotuneConfig::new(ResourceBudget::unlimited());
        cfg.patience = 2;
        cfg.background = false;
        cfg.margin_frac = 0.75;
        cfg.min_corpus = 64;
        let (mut tuner, mut join) = autotuner_on_pool(cfg, Arc::new(EmptySearchTrainer));
        tuner.install(good).unwrap();

        // Healthy unlabeled windows build the margin baseline.
        tuner.observe_unlabeled(&clean.xs).unwrap();
        tuner.observe_unlabeled(&clean.xs).unwrap();
        // Sustained margin collapse on unlabeled windows declares
        // drift with NO labels at all…
        tuner.observe_unlabeled(&drifted.xs).unwrap();
        tuner.observe_unlabeled(&drifted.xs).unwrap();
        let drift_events = tuner
            .report
            .events
            .iter()
            .filter(|e| matches!(e, AutotuneEvent::DriftDetected { accuracy: None, .. }))
            .count();
        assert_eq!(drift_events, 1, "margin-only trigger: {:?}", tuner.report.events);
        // …but with ZERO labeled corpus the retrain is starved, not
        // launched on garbage.
        assert!(tuner
            .report
            .events
            .iter()
            .any(|e| matches!(e, AutotuneEvent::RetrainStarved { corpus: 0, .. })));
        assert!(!tuner
            .report
            .events
            .iter()
            .any(|e| matches!(e, AutotuneEvent::SearchCompleted { .. })));
        assert!(tuner.report.windows.iter().all(|w| w.accuracy.is_none()));

        // Delayed labels backfill window 0: accuracy lands in the
        // report, the corpus grows, and NOTHING re-triggers.
        let n_events = tuner.report.events.len();
        let acc = tuner.backfill_labels(0, &clean.ys).unwrap().expect("window 0 pending");
        assert_eq!(tuner.report.windows[0].accuracy, Some(acc));
        assert!(acc > 0.8, "clean-window backfill accuracy {acc}");
        assert_eq!(tuner.report.events.len(), n_events + 1);
        assert!(matches!(
            tuner.report.events.last(),
            Some(AutotuneEvent::LabelsBackfilled { window: 0, .. })
        ));
        // Unknown / aged-out windows: None, not an error.
        assert!(tuner.backfill_labels(99, &clean.ys).unwrap().is_none());
        // Label-count mismatch is a typed error and records nothing.
        assert!(matches!(
            tuner.backfill_labels(1, &clean.ys[..10]),
            Err(crate::coordinator::ServeError::Core(
                crate::accel::core::CoreError::BadBatch { .. }
            ))
        ));
        assert!(tuner.report.windows[1].accuracy.is_none());

        // With the corpus backfilled past min_corpus, the next
        // sustained collapse DOES launch the search.
        tuner.observe_unlabeled(&drifted.xs).unwrap();
        tuner.observe_unlabeled(&drifted.xs).unwrap();
        assert!(
            tuner
                .report
                .events
                .iter()
                .any(|e| matches!(e, AutotuneEvent::SearchCompleted { .. })),
            "backfilled corpus must unblock the retrain: {:?}",
            tuner.report.events
        );
        tuner.handle.shutdown();
        join.join();
    }

    // ---- canary gate: reject restores, promote broadcasts -------------

    #[test]
    fn canary_gate_rejects_bad_candidate_without_exposing_it() {
        let clean = dataset(0.0, 256, 7);
        let drifted = dataset(0.35, 256, 7);
        let good = trained(&clean);
        let bad = TMModel::empty(shape());

        let mut cfg = AutotuneConfig::new(ResourceBudget::unlimited());
        cfg.patience = 1;
        cfg.background = false;
        cfg.canary_fraction = 0.25;
        cfg.canary_min_windows = 2;
        let (handle, mut join) = spawn_pool(EngineSpec::base(), 2);
        let mut tuner = Autotuner::with_trainer(handle, shape(), cfg, Arc::new(FixedTrainer(bad)));
        tuner.install(good.clone()).unwrap();
        let before = tuner.handle.infer(clean.xs.clone()).unwrap();

        // Trigger: the candidate is staged on ONE replica only.
        tuner.observe_window(&drifted.xs, &drifted.ys).unwrap();
        assert_eq!(tuner.phase_name(), "canarying");
        assert!(tuner.handle.canary_replica().is_some());
        // Live traffic during the evaluation never sees the candidate.
        assert_eq!(tuner.handle.infer(clean.xs.clone()).unwrap(), before);

        // Two losing mirror windows -> unanimous reject.
        tuner.observe_window(&drifted.xs, &drifted.ys).unwrap();
        tuner.observe_window(&drifted.xs, &drifted.ys).unwrap();
        assert_eq!(tuner.phase_name(), "monitoring");
        assert!(tuner.handle.canary_replica().is_none());
        assert!(tuner
            .report
            .events
            .iter()
            .any(|e| matches!(e, AutotuneEvent::CanaryRejected { evaluated: 2, .. })));
        assert!(!tuner
            .report
            .events
            .iter()
            .any(|e| matches!(e, AutotuneEvent::Swapped { .. })));
        // The outcome is recorded with its paired windows, all losses.
        assert_eq!(tuner.report.canaries.len(), 1);
        let outcome = &tuner.report.canaries[0];
        assert!(matches!(outcome.verdict, crate::coordinator::CanaryVerdict::Reject));
        assert_eq!(outcome.windows.len(), 2);
        assert!(outcome.windows.iter().all(|w| !w.candidate_wins));
        // The pool still serves the old model everywhere; versions are
        // install(1) + canary(2) + dismiss(3).
        assert_eq!(tuner.handle.infer(clean.xs.clone()).unwrap(), before);
        assert_eq!(tuner.current_model().unwrap(), &good);
        assert_eq!(tuner.handle.pool_stats().version, 3);
        tuner.handle.shutdown();
        join.join();
    }

    #[test]
    fn canary_gate_promotes_good_candidate_and_rebaselines_margins() {
        let clean = dataset(0.0, 256, 7);
        let drifted = dataset(0.5, 256, 7);
        let good = trained(&clean);
        let better = trained(&drifted);

        let mut cfg = AutotuneConfig::new(ResourceBudget::unlimited());
        cfg.patience = 2;
        cfg.background = false;
        cfg.canary_fraction = 0.25;
        cfg.canary_min_windows = 1;
        cfg.validation_windows = 1;
        // Aggressive margin hysteresis: after the promote, a stale
        // clean-data EWMA baseline would flag nearly any margin shift
        // as collapse — the accept path must re-baseline instead.
        cfg.margin_frac = 0.95;
        let (handle, mut join) = spawn_pool(EngineSpec::base(), 2);
        let mut tuner =
            Autotuner::with_trainer(handle, shape(), cfg, Arc::new(FixedTrainer(better.clone())));
        tuner.install(good).unwrap();

        tuner.observe_window(&clean.xs, &clean.ys).unwrap(); // baseline
        tuner.observe_window(&drifted.xs, &drifted.ys).unwrap(); // bad 1
        tuner.observe_window(&drifted.xs, &drifted.ys).unwrap(); // trigger -> canary
        assert_eq!(tuner.phase_name(), "canarying");
        tuner.observe_window(&drifted.xs, &drifted.ys).unwrap(); // win -> promote
        assert_eq!(tuner.phase_name(), "validating");
        assert!(tuner
            .report
            .events
            .iter()
            .any(|e| matches!(e, AutotuneEvent::CanaryPromoted { evaluated: 1, .. })));
        assert!(tuner
            .report
            .events
            .iter()
            .any(|e| matches!(e, AutotuneEvent::Swapped { .. })));
        tuner.observe_window(&drifted.xs, &drifted.ys).unwrap(); // validate -> accept
        assert!(tuner
            .report
            .events
            .iter()
            .any(|e| matches!(e, AutotuneEvent::Accepted { .. })));
        assert_eq!(tuner.current_model().unwrap(), &better);

        // Post-acceptance: the margin EWMA re-baselined to the NEW
        // model's scale, so steady drifted windows must not re-trigger
        // (no retune storm).
        for _ in 0..4 {
            tuner.observe_window(&drifted.xs, &drifted.ys).unwrap();
        }
        let drift_events = tuner
            .report
            .events
            .iter()
            .filter(|e| matches!(e, AutotuneEvent::DriftDetected { .. }))
            .count();
        assert_eq!(drift_events, 1, "retune storm after promote: {:?}", tuner.report.events);
        // Versions: install(1) + canary(2) + promote(3), strictly
        // monotone, and the promoted outcome is on record.
        assert_eq!(tuner.handle.pool_stats().version, 3);
        assert_eq!(tuner.report.canaries.len(), 1);
        assert!(matches!(
            tuner.report.canaries[0].verdict,
            crate::coordinator::CanaryVerdict::Promote
        ));
        tuner.handle.shutdown();
        join.join();
    }

    #[test]
    fn report_json_is_well_formed_and_complete() {
        let clean = dataset(0.0, 128, 7);
        let good = trained(&clean);
        let mut cfg = AutotuneConfig::new(ResourceBudget::unlimited());
        cfg.background = false;
        let (mut tuner, mut join) = autotuner_on_pool(cfg, Arc::new(EmptySearchTrainer));
        tuner.install(good).unwrap();
        tuner.observe_window(&clean.xs, &clean.ys).unwrap();
        tuner.observe_unlabeled(&clean.xs).unwrap();
        let json = tuner.report.to_json();
        // Structural pins (no JSON parser in the vendor set): the three
        // top-level arrays, a labeled and an unlabeled window.
        assert!(json.starts_with("{\n"));
        assert!(json.ends_with("}\n"));
        for key in ["\"windows\":", "\"events\":", "\"canaries\":"] {
            assert!(json.contains(key), "missing {key} in {json}");
        }
        assert!(json.contains("\"accuracy\": null"), "unlabeled window must be null");
        assert!(json.contains("\"model_version\": 1"));
        // Balanced braces/brackets (cheap well-formedness check).
        let balance = |open: char, close: char| {
            json.chars().filter(|&c| c == open).count()
                == json.chars().filter(|&c| c == close).count()
        };
        assert!(balance('{', '}'));
        assert!(balance('[', ']'));
        tuner.handle.shutdown();
        join.join();
    }

    // ---- no-winner search resumes monitoring --------------------------

    struct EmptySearchTrainer;

    impl ShadowTrainer for EmptySearchTrainer {
        fn retrain(&self, _train: &Dataset, _valid: &Dataset) -> BudgetedSearch {
            BudgetedSearch { trials: Vec::new(), winner: None }
        }
    }

    #[test]
    fn no_candidate_resumes_monitoring() {
        let clean = dataset(0.0, 128, 7);
        let drifted = dataset(0.35, 128, 7);
        let good = trained(&clean);
        let mut cfg = AutotuneConfig::new(ResourceBudget::unlimited());
        cfg.patience = 1;
        cfg.background = false;
        let (mut tuner, mut join) = autotuner_on_pool(cfg, Arc::new(EmptySearchTrainer));
        tuner.install(good).unwrap();
        tuner.observe_window(&drifted.xs, &drifted.ys).unwrap();
        assert!(tuner
            .report
            .events
            .iter()
            .any(|e| matches!(e, AutotuneEvent::NoCandidateFitsBudget { .. })));
        assert_eq!(tuner.phase_name(), "monitoring");
        assert_eq!(tuner.handle.pool_stats().version, 1);
        tuner.handle.shutdown();
        join.join();
    }

    // ---- online feedback: recover cheap, escalate when it fails -------

    /// Proves zero retrains by construction: any retrain panics.
    struct NeverTrainer;

    impl ShadowTrainer for NeverTrainer {
        fn retrain(&self, _train: &Dataset, _valid: &Dataset) -> BudgetedSearch {
            panic!("online feedback must recover without a shape search");
        }
    }

    #[test]
    fn online_feedback_recovers_without_a_search() {
        let clean = dataset(0.0, 256, 7);
        let drifted = dataset(0.4, 256, 7);
        let good = trained(&clean);
        let mut cfg = AutotuneConfig::new(ResourceBudget::unlimited());
        cfg.patience = 2;
        cfg.background = false;
        cfg.online_feedback = true;
        cfg.online_patience = 12; // plenty of cheap-path budget
        let (mut tuner, mut join) = autotuner_on_pool(cfg, Arc::new(NeverTrainer));
        tuner.install(good).unwrap();

        tuner.observe_window(&clean.xs, &clean.ys).unwrap();
        // Labeled drifted windows: trigger, then feed until recovered.
        let mut recovered = false;
        for _ in 0..12 {
            tuner.observe_window(&drifted.xs, &drifted.ys).unwrap();
            if tuner
                .report
                .events
                .iter()
                .any(|e| matches!(e, AutotuneEvent::OnlineRecovered { .. }))
            {
                recovered = true;
                break;
            }
        }
        assert!(recovered, "cheap path never recovered: {:?}", tuner.report.events);
        assert_eq!(tuner.phase_name(), "monitoring");
        let fed = tuner
            .report
            .events
            .iter()
            .filter(|e| matches!(e, AutotuneEvent::OnlineFeedback { .. }))
            .count();
        assert!(fed >= 1, "recovery must come from feedback windows");
        // NeverTrainer would have panicked, but pin it in the record
        // too: no search-path events of any kind.
        assert!(!tuner.report.events.iter().any(|e| matches!(
            e,
            AutotuneEvent::SearchCompleted { .. }
                | AutotuneEvent::Swapped { .. }
                | AutotuneEvent::OnlineEscalated { .. }
        )));
        // Every feedback was a fence-gated broadcast: install(1) + fed.
        assert_eq!(tuner.handle.pool_stats().version, 1 + fed as u64);
        // And the pool now actually serves well on the drifted stream.
        let preds = tuner.handle.infer(drifted.xs.clone()).unwrap();
        let acc = preds.iter().zip(&drifted.ys).filter(|(p, y)| p == y).count() as f64
            / drifted.ys.len() as f64;
        assert!(acc >= 0.85, "post-recovery accuracy {acc}");
        tuner.handle.shutdown();
        join.join();
    }

    #[test]
    fn online_feedback_escalates_to_search_after_patience() {
        let clean = dataset(0.0, 256, 7);
        let drifted = dataset(0.35, 256, 7);
        let good = trained(&clean);
        let fixed = trained(&drifted);
        let mut cfg = AutotuneConfig::new(ResourceBudget::unlimited());
        // A floor no window can reach makes recovery impossible: the
        // escalation path is exercised deterministically.
        cfg.accuracy_floor = 1.01;
        cfg.patience = 2;
        cfg.online_feedback = true;
        cfg.online_patience = 2;
        cfg.min_gain = -1.0; // validation keeps any swap
        cfg.validation_windows = 1;
        cfg.canary_fraction = 0.0; // direct swap (1-replica pool)
        cfg.background = false;
        let (mut tuner, mut join) = autotuner_on_pool(cfg, Arc::new(FixedTrainer(fixed)));
        tuner.install(good).unwrap();

        tuner.observe_window(&clean.xs, &clean.ys).unwrap(); // bad 1
        tuner.observe_window(&clean.xs, &clean.ys).unwrap(); // trigger, feed #1
        assert_eq!(tuner.phase_name(), "feeding_back");
        tuner.observe_window(&clean.xs, &clean.ys).unwrap(); // feed #2 → escalate
        let events = &tuner.report.events;
        assert!(
            events.iter().any(|e| matches!(
                e,
                AutotuneEvent::OnlineEscalated { fed_windows: 2, .. }
            )),
            "expected escalation after 2 fed windows: {events:?}"
        );
        assert!(events.iter().any(|e| matches!(e, AutotuneEvent::SearchCompleted { .. })));
        assert!(events.iter().any(|e| matches!(e, AutotuneEvent::Swapped { .. })));
        // install(1) + 2 feedback fences + the swap: strictly monotone.
        assert_eq!(tuner.handle.pool_stats().version, 4);
        tuner.handle.shutdown();
        join.join();
    }

    #[test]
    fn label_starved_feedback_escalates_at_the_horizon() {
        let clean = dataset(0.0, 256, 7);
        let drifted = dataset(0.5, 256, 7);
        let good = trained(&clean);
        let mut cfg = AutotuneConfig::new(ResourceBudget::unlimited());
        cfg.patience = 2;
        cfg.margin_frac = 0.75;
        cfg.online_feedback = true;
        cfg.online_patience = 2;
        cfg.label_backfill_horizon = 2; // escape at streak >= 4
        cfg.min_corpus = 64;
        cfg.background = false;
        let (mut tuner, mut join) = autotuner_on_pool(cfg, Arc::new(EmptySearchTrainer));
        tuner.install(good).unwrap();

        // Labeled healthy windows: margin baseline + retrain corpus.
        tuner.observe_window(&clean.xs, &clean.ys).unwrap();
        tuner.observe_window(&clean.xs, &clean.ys).unwrap();
        // Unlabeled margin collapse with labels that never arrive: the
        // cheap path has nothing to feed and must not wedge.
        for _ in 0..6 {
            tuner.observe_unlabeled(&drifted.xs).unwrap();
        }
        let events = &tuner.report.events;
        assert!(
            events.iter().any(|e| matches!(
                e,
                AutotuneEvent::OnlineEscalated { fed_windows: 0, .. }
            )),
            "label-starved cheap path must escalate: {events:?}"
        );
        assert!(!events.iter().any(|e| matches!(e, AutotuneEvent::OnlineFeedback { .. })));
        tuner.handle.shutdown();
        join.join();
    }
}
