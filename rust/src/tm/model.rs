//! Dense TM model: the Include/Exclude action of every TA.
//!
//! For inference only the 1-bit action matters (paper §2): a trained model
//! is fully described by its include set.  This struct is the bridge
//! between every representation in the system:
//!
//! * the trainer's TA states (`from_ta_states`),
//! * the PJRT inference artifact's `u32` include mask (`to_packed_mask`),
//! * the ISA compressor (`isa::encode`), and
//! * the reference/simulator inference paths.

use crate::config::TMShape;

/// Dense include map, row-major `[class][clause][literal]`.
#[derive(Debug, Clone, PartialEq)]
pub struct TMModel {
    pub shape: TMShape,
    include: Vec<bool>,
}

impl TMModel {
    pub fn empty(shape: TMShape) -> Self {
        let n = shape.total_tas();
        TMModel {
            shape,
            include: vec![false; n],
        }
    }

    /// Build from trainer TA states (include iff state >= N).
    pub fn from_ta_states(shape: TMShape, states: &[i32]) -> Self {
        assert_eq!(states.len(), shape.total_tas());
        let n = shape.n_states;
        TMModel {
            include: states.iter().map(|&s| s >= n).collect(),
            shape,
        }
    }

    #[inline]
    fn idx(&self, class: usize, clause: usize, literal: usize) -> usize {
        debug_assert!(class < self.shape.classes);
        debug_assert!(clause < self.shape.clauses);
        debug_assert!(literal < self.shape.literals());
        (class * self.shape.clauses + clause) * self.shape.literals() + literal
    }

    #[inline]
    pub fn include(&self, class: usize, clause: usize, literal: usize) -> bool {
        self.include[self.idx(class, clause, literal)]
    }

    pub fn set_include(&mut self, class: usize, clause: usize, literal: usize, v: bool) {
        let i = self.idx(class, clause, literal);
        self.include[i] = v;
    }

    /// Clause polarity: +1 for even clause index, -1 for odd (restarts per
    /// class — matches the ISA's +/- bit and the L1 class-sum kernel).
    #[inline]
    pub fn polarity(clause: usize) -> i32 {
        1 - 2 * (clause as i32 & 1)
    }

    /// Includes of one clause as literal indices (the compressed walk of
    /// Fig 3.3 visits exactly these, in order).
    pub fn clause_includes(&self, class: usize, clause: usize) -> Vec<usize> {
        let l = self.shape.literals();
        let base = self.idx(class, clause, 0);
        (0..l).filter(|&lit| self.include[base + lit]).collect()
    }

    /// Total include count (the paper's ~1% sparsity claim: ~17k of
    /// 3,136,000 for MNIST).
    pub fn include_count(&self) -> usize {
        self.include.iter().filter(|&&b| b).count()
    }

    /// Include fraction in [0,1].
    pub fn sparsity(&self) -> f64 {
        self.include_count() as f64 / self.include.len() as f64
    }

    /// Include counts per class — drives multi-core load balance (Fig 7).
    pub fn includes_per_class(&self) -> Vec<usize> {
        (0..self.shape.classes)
            .map(|m| {
                (0..self.shape.clauses)
                    .map(|c| self.clause_includes(m, c).len())
                    .sum()
            })
            .collect()
    }

    /// The `u32[K, L]` include mask consumed by the PJRT inference
    /// artifact: 0xFFFF_FFFF where Include, 0 where Exclude, class-major.
    pub fn to_packed_mask(&self) -> Vec<u32> {
        self.include
            .iter()
            .map(|&b| if b { u32::MAX } else { 0 })
            .collect()
    }

    /// Restrict the model to a contiguous class range (multi-core sharding:
    /// each core receives the instructions of its classes only, Fig 7).
    pub fn slice_classes(&self, range: std::ops::Range<usize>) -> TMModel {
        assert!(range.end <= self.shape.classes);
        let l = self.shape.literals();
        let per_class = self.shape.clauses * l;
        let mut shape = self.shape.clone();
        shape.classes = range.len();
        shape.name = format!("{}[{}..{}]", self.shape.name, range.start, range.end);
        TMModel {
            shape,
            include: self.include[range.start * per_class..range.end * per_class].to_vec(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> TMModel {
        let mut m = TMModel::empty(TMShape::synthetic(3, 2, 4));
        m.set_include(0, 0, 1, true);
        m.set_include(0, 3, 5, true);
        m.set_include(1, 2, 0, true);
        m
    }

    #[test]
    fn include_roundtrip() {
        let m = tiny();
        assert!(m.include(0, 0, 1));
        assert!(m.include(0, 3, 5));
        assert!(m.include(1, 2, 0));
        assert!(!m.include(0, 0, 0));
        assert_eq!(m.include_count(), 3);
    }

    #[test]
    fn polarity_alternates_from_positive() {
        assert_eq!(TMModel::polarity(0), 1);
        assert_eq!(TMModel::polarity(1), -1);
        assert_eq!(TMModel::polarity(2), 1);
    }

    #[test]
    fn from_ta_states_threshold() {
        let shape = TMShape::synthetic(2, 2, 2);
        let mut states = vec![127i32; shape.total_tas()];
        states[0] = 128;
        states[5] = 255;
        let m = TMModel::from_ta_states(shape, &states);
        assert_eq!(m.include_count(), 2);
        assert!(m.include(0, 0, 0));
    }

    #[test]
    fn packed_mask_values() {
        let m = tiny();
        let mask = m.to_packed_mask();
        assert_eq!(mask.len(), m.shape.total_tas());
        assert_eq!(mask[1], u32::MAX); // class 0, clause 0, literal 1
        assert_eq!(mask[0], 0);
    }

    #[test]
    fn class_slice_keeps_rows() {
        let m = tiny();
        let s = m.slice_classes(1..2);
        assert_eq!(s.shape.classes, 1);
        assert!(s.include(0, 2, 0));
        assert_eq!(s.include_count(), 1);
    }

    #[test]
    fn includes_per_class_counts() {
        let m = tiny();
        assert_eq!(m.includes_per_class(), vec![2, 1]);
    }

    #[test]
    fn sparsity_fraction() {
        let m = tiny();
        let total = m.shape.total_tas() as f64;
        assert!((m.sparsity() - 3.0 / total).abs() < 1e-12);
    }
}
