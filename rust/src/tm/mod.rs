//! Tsetlin Machine substrate: models, booleanization, reference inference.

pub mod booleanize;
pub mod model;
pub mod reference;
pub mod serialize;

pub use model::TMModel;
pub use reference::{class_sums_dense, predict_dense};
