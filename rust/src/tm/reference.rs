//! Dense reference inference — the golden semantics every other path
//! (ISA-compressed simulator, PJRT packed artifact, MCU interpreter) must
//! reproduce exactly.
//!
//! Mirrors `python/compile/kernels/ref.py` (`clause_eval_dense_ref` with
//! inference semantics + per-class alternating polarity).

use super::model::TMModel;

/// Literal vector (len 2F, values 0/1) from a booleanized feature vector.
/// Interleaved: literal 2f = x_f, literal 2f+1 = !x_f.
pub fn literals_from_features(features: &[u8]) -> Vec<u8> {
    let mut lit = Vec::with_capacity(features.len() * 2);
    for &f in features {
        debug_assert!(f <= 1);
        lit.push(f);
        lit.push(1 - f);
    }
    lit
}

/// One clause output with inference semantics (empty clause -> 0).
pub fn clause_output(model: &TMModel, class: usize, clause: usize, literals: &[u8]) -> bool {
    let mut any = false;
    for lit in 0..model.shape.literals() {
        if model.include(class, clause, lit) {
            any = true;
            if literals[lit] == 0 {
                return false;
            }
        }
    }
    any
}

/// Per-class sums for one datapoint (Fig 3.1).
pub fn class_sums_dense(model: &TMModel, literals: &[u8]) -> Vec<i32> {
    assert_eq!(literals.len(), model.shape.literals());
    (0..model.shape.classes)
        .map(|m| {
            (0..model.shape.clauses)
                .map(|c| {
                    if clause_output(model, m, c, literals) {
                        TMModel::polarity(c)
                    } else {
                        0
                    }
                })
                .sum()
        })
        .collect()
}

/// argmax class (ties -> lowest index, matching jnp.argmax).
pub fn predict_dense(model: &TMModel, literals: &[u8]) -> usize {
    argmax(&class_sums_dense(model, literals))
}

/// Accuracy over a booleanized dataset (features, not literals).
pub fn accuracy(model: &TMModel, xs: &[Vec<u8>], ys: &[usize]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    let correct = xs
        .iter()
        .zip(ys)
        .filter(|(x, &y)| predict_dense(model, &literals_from_features(x)) == y)
        .count();
    correct as f64 / xs.len().max(1) as f64
}

/// First-max argmax, identical tie-breaking to `jnp.argmax`.
pub fn argmax(sums: &[i32]) -> usize {
    let mut best = 0;
    for (i, &v) in sums.iter().enumerate() {
        if v > sums[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TMShape;

    fn model_and() -> TMModel {
        // One class, two clauses. Clause 0 (+) = x0 AND !x1; clause 1 (-)
        // = x1.
        let mut m = TMModel::empty(TMShape::synthetic(2, 1, 2));
        m.set_include(0, 0, 0, true); // literal 0 = x0
        m.set_include(0, 0, 3, true); // literal 3 = !x1
        m.set_include(0, 1, 2, true); // literal 2 = x1
        m
    }

    #[test]
    fn literals_interleaved() {
        assert_eq!(literals_from_features(&[1, 0]), vec![1, 0, 0, 1]);
    }

    #[test]
    fn clause_and_semantics() {
        let m = model_and();
        let lit = literals_from_features(&[1, 0]);
        assert!(clause_output(&m, 0, 0, &lit)); // x0=1, x1=0
        assert!(!clause_output(&m, 0, 1, &lit));
        let lit = literals_from_features(&[1, 1]);
        assert!(!clause_output(&m, 0, 0, &lit));
        assert!(clause_output(&m, 0, 1, &lit));
    }

    #[test]
    fn empty_clause_is_zero_at_inference() {
        let m = TMModel::empty(TMShape::synthetic(2, 1, 2));
        let lit = literals_from_features(&[1, 1]);
        assert!(!clause_output(&m, 0, 0, &lit));
        assert_eq!(class_sums_dense(&m, &lit), vec![0]);
    }

    #[test]
    fn polarity_signs_sums() {
        let m = model_and();
        // x0=1,x1=0: only +clause fires -> +1.
        assert_eq!(class_sums_dense(&m, &literals_from_features(&[1, 0])), vec![1]);
        // x0=1,x1=1: only -clause fires -> -1.
        assert_eq!(class_sums_dense(&m, &literals_from_features(&[1, 1])), vec![-1]);
    }

    #[test]
    fn argmax_first_max_tiebreak() {
        assert_eq!(argmax(&[3, 5, 5, 1]), 1);
        assert_eq!(argmax(&[0, 0]), 0);
        assert_eq!(argmax(&[-5, -2, -2]), 1);
    }

    #[test]
    fn accuracy_counts() {
        let m = model_and();
        // Model has one class; everything predicts class 0.
        let xs = vec![vec![1, 0], vec![0, 1]];
        let ys = vec![0usize, 0];
        assert_eq!(accuracy(&m, &xs, &ys), 1.0);
    }
}
