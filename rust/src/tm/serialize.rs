//! `.rttm` model files: the portable artifact the Model Training Node
//! hands to deployments (and what a field tool would flash over the
//! network).  Contains the shape and the *compressed instruction
//! stream* — the dense model is redundant (paper §2: includes are the
//! model).
//!
//! Layout (little endian):
//! ```text
//! magic   "RTTM"            4 B
//! version u16               (1 = unnamed, 2 = named-model extension)
//! name    u16 len + bytes   (shape/architecture name)
//! features/classes/clauses  u32 x 3
//! T       i32
//! s_milli u32               (s * 1000, fixed point)
//! -- version 2 only --------------------------------------------
//! deploy  u16 len + bytes   (deployment/tenant name)
//! hash    u64               FNV-1a-64 of the model's v1 wire bytes
//! --------------------------------------------------------------
//! count   u32               instruction count
//! instrs  count x u16
//! crc32   u32               over everything above
//! ```
//!
//! Version 2 is a strict header extension for the multi-model registry:
//! the deployment name labels the tenant/application the file belongs
//! to, and the content hash pins the payload to its canonical v1
//! serialization so a registry can dedup without decoding, and a
//! swapped-stream splice under a stale tag is rejected at load.
//! Version 1 files load unchanged (tag absent).

use crate::config::TMShape;
use crate::isa::{self, Instr};
use crate::tm::model::TMModel;
use std::io::{Read, Write};

const MAGIC: &[u8; 4] = b"RTTM";
const VERSION: u16 = 1;
/// Minor wire version carrying the named-model header extension.
pub const VERSION_NAMED: u16 = 2;
/// Longest shape/deployment name the u16 length prefix can frame.
pub const MAX_NAME_LEN: usize = u16::MAX as usize;

/// Errors loading a model file.
#[derive(Debug, thiserror::Error)]
pub enum FileError {
    #[error("io: {0}")]
    Io(#[from] std::io::Error),
    #[error("not an RTTM file")]
    BadMagic,
    /// The file ends before a declared field does.  Distinct from
    /// [`FileError::BadMagic`]: an adversarial file can be CRC-valid
    /// yet *claim* more payload than it carries.
    #[error("truncated file: {needed} more bytes required")]
    Truncated { needed: usize },
    /// The file carries MORE payload than its fields declare (e.g. a
    /// CRC-resealed `count` understated by one).  The inverse of
    /// [`FileError::Truncated`]: undeclared bytes are never silently
    /// ignored — they would be an unauthenticated side channel.
    #[error("malformed file: {extra} undeclared trailing bytes")]
    TrailingBytes { extra: usize },
    #[error("unsupported version {0}")]
    BadVersion(u16),
    #[error("checksum mismatch (corrupted file)")]
    BadCrc,
    /// A v2 named-model tag's content hash disagrees with the payload
    /// it frames: the instruction stream was swapped or spliced under a
    /// stale tag.  The CRC cannot catch this (an adversary reseals it);
    /// the content hash is recomputed from the decoded payload's
    /// canonical v1 bytes instead of trusted from the header.
    #[error("named-model tag mismatch: tag claims {stored:#018x}, payload hashes to {computed:#018x}")]
    TagMismatch { stored: u64, computed: u64 },
    #[error("malformed stream: {0}")]
    BadStream(#[from] isa::IsaError),
    /// A shape or deployment name longer than the wire format's u16
    /// length field can frame.  Rejected at save time: the unchecked
    /// `len as u16` cast used to truncate the length field and emit a
    /// CRC-valid but unreadable file.
    #[error("{field} name is {len} bytes; the .rttm name length field caps at {MAX_NAME_LEN}")]
    NameTooLong { field: &'static str, len: usize },
    /// The decoded stream carries more clauses of one polarity than the
    /// declared shape has slots for (each polarity owns half the clause
    /// indices) — a forged shape/stream combination.
    #[error("stream decodes to more clauses than the declared shape holds")]
    ShapeOverflow,
}

/// The v2 named-model header extension.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ModelTag {
    /// Deployment/tenant name (NOT the shape name, which tracks
    /// architecture).
    pub name: String,
    /// FNV-1a-64 over the model's canonical v1 wire bytes — the same
    /// digest the model registry dedups on.
    pub content_hash: u64,
}

/// CRC-32 (IEEE, bitwise — cold path, no table needed).
pub fn crc32(data: &[u8]) -> u32 {
    let mut crc = 0xFFFF_FFFFu32;
    for &b in data {
        crc ^= b as u32;
        for _ in 0..8 {
            let mask = (crc & 1).wrapping_neg();
            crc = (crc >> 1) ^ (0xEDB8_8320 & mask);
        }
    }
    !crc
}

/// FNV-1a 64-bit: the registry's content digest.  Not cryptographic —
/// it guards against accidents and splices, not a determined forger
/// (who would need to also forge the payload that hashes to it).
pub fn fnv1a64(data: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in data {
        h ^= u64::from(b);
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Content hash of a model: FNV-1a-64 over its canonical v1 wire bytes
/// (CRC trailer included).  Identical models — same shape, same include
/// set — hash identically regardless of deployment name.
pub fn content_hash(model: &TMModel) -> u64 {
    fnv1a64(&to_bytes(model))
}

fn put_u16(buf: &mut Vec<u8>, v: u16) {
    buf.extend_from_slice(&v.to_le_bytes());
}
fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}
fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

/// Shared v1 header + stream writer (no CRC): `to_bytes` seals this
/// directly; the v2 hash verification replays it from decoded fields.
fn v1_body(shape: &TMShape, instrs: &[Instr]) -> Vec<u8> {
    let mut buf = Vec::with_capacity(32 + shape.name.len() + 2 * instrs.len());
    buf.extend_from_slice(MAGIC);
    put_u16(&mut buf, VERSION);
    put_u16(&mut buf, shape.name.len() as u16);
    buf.extend_from_slice(shape.name.as_bytes());
    put_u32(&mut buf, shape.features as u32);
    put_u32(&mut buf, shape.classes as u32);
    put_u32(&mut buf, shape.clauses as u32);
    buf.extend_from_slice(&shape.t.to_le_bytes());
    put_u32(&mut buf, (shape.s * 1000.0).round() as u32);
    put_u32(&mut buf, instrs.len() as u32);
    for i in instrs {
        put_u16(&mut buf, i.0);
    }
    buf
}

fn seal(mut buf: Vec<u8>) -> Vec<u8> {
    let crc = crc32(&buf);
    put_u32(&mut buf, crc);
    buf
}

/// Serialize a model (shape + compressed stream) to v1 bytes —
/// byte-identical to every file this writer has ever produced.
pub fn to_bytes(model: &TMModel) -> Vec<u8> {
    seal(v1_body(&model.shape, &isa::encode(model)))
}

/// Serialize a model as a v2 named file: v1 fields plus the deployment
/// name and the payload's canonical content hash.
pub fn to_bytes_named(model: &TMModel, deploy_name: &str) -> Vec<u8> {
    let instrs = isa::encode(model);
    let hash = fnv1a64(&seal(v1_body(&model.shape, &instrs)));
    let mut buf =
        Vec::with_capacity(48 + model.shape.name.len() + deploy_name.len() + 2 * instrs.len());
    buf.extend_from_slice(MAGIC);
    put_u16(&mut buf, VERSION_NAMED);
    put_u16(&mut buf, model.shape.name.len() as u16);
    buf.extend_from_slice(model.shape.name.as_bytes());
    put_u32(&mut buf, model.shape.features as u32);
    put_u32(&mut buf, model.shape.classes as u32);
    put_u32(&mut buf, model.shape.clauses as u32);
    buf.extend_from_slice(&model.shape.t.to_le_bytes());
    put_u32(&mut buf, (model.shape.s * 1000.0).round() as u32);
    put_u16(&mut buf, deploy_name.len() as u16);
    buf.extend_from_slice(deploy_name.as_bytes());
    put_u64(&mut buf, hash);
    put_u32(&mut buf, instrs.len() as u32);
    for i in &instrs {
        put_u16(&mut buf, i.0);
    }
    seal(buf)
}

struct Cursor<'a> {
    data: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8], FileError> {
        if self.pos + n > self.data.len() {
            return Err(FileError::Truncated { needed: self.pos + n - self.data.len() });
        }
        let s = &self.data[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }
    fn u16(&mut self) -> Result<u16, FileError> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }
    fn u32(&mut self) -> Result<u32, FileError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn i32(&mut self) -> Result<i32, FileError> {
        Ok(i32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn u64(&mut self) -> Result<u64, FileError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
}

/// Parse bytes back into (shape, instruction stream, optional named-
/// model tag), verifying CRC, stream well-formedness, and — for v2
/// files — that the tag's content hash matches the payload.
pub fn from_bytes_full(data: &[u8]) -> Result<(TMShape, Vec<Instr>, Option<ModelTag>), FileError> {
    // Minimum framing: magic + at least the CRC trailer.
    if data.len() < 8 {
        return Err(FileError::Truncated { needed: 8 - data.len() });
    }
    let (body, crc_bytes) = data.split_at(data.len() - 4);
    let stored = u32::from_le_bytes(crc_bytes.try_into().unwrap());
    if crc32(body) != stored {
        return Err(FileError::BadCrc);
    }
    let mut c = Cursor { data: body, pos: 0 };
    if c.take(4)? != MAGIC {
        return Err(FileError::BadMagic);
    }
    let version = c.u16()?;
    if version != VERSION && version != VERSION_NAMED {
        return Err(FileError::BadVersion(version));
    }
    let name_len = c.u16()? as usize;
    let name = String::from_utf8_lossy(c.take(name_len)?).into_owned();
    let features = c.u32()? as usize;
    let classes = c.u32()? as usize;
    let clauses = c.u32()? as usize;
    let t = c.i32()?;
    let s = c.u32()? as f64 / 1000.0;
    let raw_tag = if version == VERSION_NAMED {
        let deploy_len = c.u16()? as usize;
        let deploy = String::from_utf8_lossy(c.take(deploy_len)?).into_owned();
        Some((deploy, c.u64()?))
    } else {
        None
    };
    let count = c.u32()? as usize;
    // Validate the declared count against the bytes actually remaining
    // BEFORE sizing any allocation: a CRC-valid adversarial file
    // claiming `count = u32::MAX` would otherwise pre-allocate ~8 GB.
    let remaining = c.data.len() - c.pos;
    if count.saturating_mul(2) > remaining {
        return Err(FileError::Truncated {
            needed: count.saturating_mul(2) - remaining,
        });
    }
    let mut instrs = Vec::with_capacity(count);
    for _ in 0..count {
        instrs.push(Instr(c.u16()?));
    }
    // Every body byte must be declared by some field: leftover bytes
    // mean the count understates the stream (or the file smuggles
    // undeclared payload past the field layout).
    if c.pos != c.data.len() {
        return Err(FileError::TrailingBytes { extra: c.data.len() - c.pos });
    }
    let shape = TMShape {
        name,
        features,
        classes,
        clauses,
        t,
        s,
        train_batch: 32,
        n_states: 128,
    };
    // Validate the stream decodes within this shape.
    isa::encoder::decode_clauses(&instrs, shape.literals(), shape.classes)?;
    let tag = match raw_tag {
        Some((deploy, claimed)) => {
            let computed = fnv1a64(&seal(v1_body(&shape, &instrs)));
            if computed != claimed {
                return Err(FileError::TagMismatch { stored: claimed, computed });
            }
            Some(ModelTag { name: deploy, content_hash: claimed })
        }
        None => None,
    };
    Ok((shape, instrs, tag))
}

/// Parse bytes back into (shape, instruction stream), verifying CRC and
/// stream well-formedness.  Accepts both wire versions; the v2 tag (if
/// any) is verified then discarded — use [`from_bytes_full`] to keep it.
pub fn from_bytes(data: &[u8]) -> Result<(TMShape, Vec<Instr>), FileError> {
    from_bytes_full(data).map(|(shape, instrs, _)| (shape, instrs))
}

/// Reject names the u16 length prefix cannot frame.  Checked BEFORE
/// `File::create`, so an oversized name never leaves a corrupt (or
/// even partial) file on disk.
fn check_name(field: &'static str, name: &str) -> Result<(), FileError> {
    if name.len() > MAX_NAME_LEN {
        return Err(FileError::NameTooLong { field, len: name.len() });
    }
    Ok(())
}

/// Write a model file (v1).
pub fn save(model: &TMModel, path: impl AsRef<std::path::Path>) -> Result<(), FileError> {
    check_name("shape", &model.shape.name)?;
    let mut f = std::fs::File::create(path)?;
    f.write_all(&to_bytes(model))?;
    Ok(())
}

/// Write a v2 named model file.
pub fn save_named(
    model: &TMModel,
    deploy_name: &str,
    path: impl AsRef<std::path::Path>,
) -> Result<(), FileError> {
    check_name("shape", &model.shape.name)?;
    check_name("deployment", deploy_name)?;
    let mut f = std::fs::File::create(path)?;
    f.write_all(&to_bytes_named(model, deploy_name))?;
    Ok(())
}

/// Read a model file.
pub fn load(path: impl AsRef<std::path::Path>) -> Result<(TMShape, Vec<Instr>), FileError> {
    let mut data = Vec::new();
    std::fs::File::open(path)?.read_to_end(&mut data)?;
    from_bytes(&data)
}

/// Read a model file, keeping the v2 named-model tag when present.
pub fn load_full(
    path: impl AsRef<std::path::Path>,
) -> Result<(TMShape, Vec<Instr>, Option<ModelTag>), FileError> {
    let mut data = Vec::new();
    std::fs::File::open(path)?.read_to_end(&mut data)?;
    from_bytes_full(&data)
}

/// Rebuild a dense model from a decoded (shape, stream) pair.  Decoded
/// clauses are placed back by polarity in stream order — positives at
/// even clause indices, negatives at odd (polarity is a fixed function
/// of the index).  Encode skips empty clauses, so indices may compact
/// relative to the model that produced the stream; class sums are
/// order-free within a polarity, so inference behavior is identical.
pub fn to_model(shape: TMShape, instrs: &[Instr]) -> Result<TMModel, FileError> {
    let decoded = isa::encoder::decode_clauses(instrs, shape.literals(), shape.classes)?;
    let mut model = TMModel::empty(shape);
    for (class, clauses) in decoded.iter().enumerate() {
        let mut next = [0usize, 1usize];
        for (polarity, literals) in clauses {
            let slot = &mut next[usize::from(*polarity < 0)];
            if *slot >= model.shape.clauses {
                return Err(FileError::ShapeOverflow);
            }
            for &lit in literals {
                model.set_include(class, *slot, lit, true);
            }
            *slot += 2;
        }
    }
    Ok(model)
}

/// Read a model file all the way back to a programmable dense model
/// (see [`to_model`]) — the loader behind `rttm serve --models`.
pub fn load_model(
    path: impl AsRef<std::path::Path>,
) -> Result<(TMModel, Option<ModelTag>), FileError> {
    let (shape, instrs, tag) = load_full(path)?;
    Ok((to_model(shape, &instrs)?, tag))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::synth::SynthSpec;

    fn trained() -> TMModel {
        let shape = TMShape::synthetic(10, 3, 6);
        let data = SynthSpec::new(10, 3, 128).noise(0.05).seed(4).generate();
        crate::trainer::train_model(&shape, &data, 3, 2)
    }

    #[test]
    fn to_model_rebuilds_an_inference_identical_model() {
        let model = trained();
        let (shape, instrs) = from_bytes(&to_bytes(&model)).unwrap();
        let rebuilt = to_model(shape, &instrs).unwrap();
        let probe = SynthSpec::new(10, 3, 64).noise(0.05).seed(9).generate();
        for x in &probe.xs {
            let lits = crate::tm::reference::literals_from_features(x);
            assert_eq!(
                crate::tm::reference::class_sums_dense(&model, &lits),
                crate::tm::reference::class_sums_dense(&rebuilt, &lits),
                "rebuilt model must produce identical class sums"
            );
        }
    }

    #[test]
    fn roundtrip_preserves_stream_and_shape() {
        let model = trained();
        let bytes = to_bytes(&model);
        let (shape, instrs) = from_bytes(&bytes).unwrap();
        assert_eq!(shape.features, model.shape.features);
        assert_eq!(shape.classes, model.shape.classes);
        assert_eq!(shape.clauses, model.shape.clauses);
        assert_eq!(shape.t, model.shape.t);
        assert!((shape.s - model.shape.s).abs() < 1e-3);
        assert_eq!(instrs, isa::encode(&model));
    }

    #[test]
    fn named_roundtrip_preserves_tag() {
        let model = trained();
        let bytes = to_bytes_named(&model, "tenant-a");
        let (shape, instrs, tag) = from_bytes_full(&bytes).unwrap();
        assert_eq!(shape.features, model.shape.features);
        assert_eq!(instrs, isa::encode(&model));
        let tag = tag.expect("v2 file must carry a tag");
        assert_eq!(tag.name, "tenant-a");
        assert_eq!(tag.content_hash, content_hash(&model));
        // The plain loader accepts v2 too, discarding the tag.
        assert!(from_bytes(&bytes).is_ok());
    }

    #[test]
    fn v1_files_load_with_no_tag() {
        let model = trained();
        let (_, _, tag) = from_bytes_full(&to_bytes(&model)).unwrap();
        assert!(tag.is_none(), "v1 files carry no named-model tag");
    }

    #[test]
    fn content_hash_ignores_deploy_name_and_separates_models() {
        let model = trained();
        // Two different deployment names frame the identical payload:
        // same content hash in both files.
        let a = from_bytes_full(&to_bytes_named(&model, "a")).unwrap().2.unwrap();
        let b = from_bytes_full(&to_bytes_named(&model, "b")).unwrap().2.unwrap();
        assert_eq!(a.content_hash, b.content_hash);
        // A different model hashes differently.
        let mut other = model.clone();
        other.set_include(0, 0, 0, !other.include(0, 0, 0));
        assert_ne!(content_hash(&other), content_hash(&model));
    }

    #[test]
    fn tampered_tag_hash_rejected_even_when_resealed() {
        let model = trained();
        let mut bytes = to_bytes_named(&model, "t");
        // The u64 hash sits right before the count field: body is
        // magic(4)+ver(2)+name(2+len)+12+4+4 + deploy(2+1) + hash(8).
        let hash_off = 4 + 2 + 2 + model.shape.name.len() + 12 + 4 + 4 + 2 + 1;
        bytes[hash_off] ^= 0xFF;
        reseal(&mut bytes);
        assert!(matches!(
            from_bytes_full(&bytes),
            Err(FileError::TagMismatch { .. })
        ));
    }

    #[test]
    fn v2_trailing_bytes_still_rejected() {
        let model = trained();
        let mut bytes = to_bytes_named(&model, "t");
        let count_off = 4 + 2 + 2 + model.shape.name.len() + 12 + 4 + 4 + 2 + 1 + 8;
        let count = u32::from_le_bytes(bytes[count_off..count_off + 4].try_into().unwrap());
        bytes[count_off..count_off + 4].copy_from_slice(&(count - 1).to_le_bytes());
        reseal(&mut bytes);
        assert!(matches!(
            from_bytes_full(&bytes),
            Err(FileError::TrailingBytes { extra: 2 })
        ));
    }

    #[test]
    fn unknown_version_rejected() {
        let model = trained();
        let mut bytes = to_bytes(&model);
        bytes[4..6].copy_from_slice(&3u16.to_le_bytes());
        reseal(&mut bytes);
        assert!(matches!(from_bytes(&bytes), Err(FileError::BadVersion(3))));
    }

    #[test]
    fn crc_catches_corruption() {
        let model = trained();
        let mut bytes = to_bytes(&model);
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x40;
        assert!(matches!(from_bytes(&bytes), Err(FileError::BadCrc)));
    }

    #[test]
    fn truncation_rejected() {
        let model = trained();
        let bytes = to_bytes(&model);
        assert!(from_bytes(&bytes[..bytes.len() / 2]).is_err());
        assert!(from_bytes(&[]).is_err());
    }

    /// Recompute and overwrite the CRC trailer so a tampered body is
    /// CRC-valid again (what an adversary controlling the file does).
    fn reseal(bytes: &mut [u8]) {
        let body = bytes.len() - 4;
        let crc = crc32(&bytes[..body]).to_le_bytes();
        bytes[body..].copy_from_slice(&crc);
    }

    #[test]
    fn adversarial_count_rejected_before_allocation() {
        let model = trained();
        let mut bytes = to_bytes(&model);
        // Offset of the `count` field: magic(4) + version(2) +
        // name_len(2) + name + 3 x u32 + i32 + u32.
        let off = 4 + 2 + 2 + model.shape.name.len() + 12 + 4 + 4;
        bytes[off..off + 4].copy_from_slice(&u32::MAX.to_le_bytes());
        reseal(&mut bytes);
        // Must fail as Truncated (count vs. remaining bytes), and fast —
        // no multi-GB Vec::with_capacity.
        assert!(matches!(
            from_bytes(&bytes),
            Err(FileError::Truncated { .. })
        ));

        // An off-by-one inflation is caught the same way.
        let mut bytes = to_bytes(&model);
        let count = u32::from_le_bytes(bytes[off..off + 4].try_into().unwrap());
        bytes[off..off + 4].copy_from_slice(&(count + 1).to_le_bytes());
        reseal(&mut bytes);
        assert!(matches!(
            from_bytes(&bytes),
            Err(FileError::Truncated { needed: 2 })
        ));

        // An off-by-one UNDERstatement leaves 2 undeclared body bytes:
        // rejected as TrailingBytes, never silently ignored.
        let mut bytes = to_bytes(&model);
        bytes[off..off + 4].copy_from_slice(&(count - 1).to_le_bytes());
        reseal(&mut bytes);
        assert!(matches!(
            from_bytes(&bytes),
            Err(FileError::TrailingBytes { extra: 2 })
        ));
    }

    #[test]
    fn truncation_mid_header_is_truncated_not_bad_magic() {
        let model = trained();
        let bytes = to_bytes(&model);
        // Cut inside the name field and re-seal the CRC: the only
        // remaining signal is the cursor running out of bytes, which
        // used to masquerade as BadMagic.
        let mut cut = bytes[..10].to_vec();
        cut.extend_from_slice(&crc32(&cut).to_le_bytes());
        assert!(matches!(from_bytes(&cut), Err(FileError::Truncated { .. })));
        // Sub-minimum files are truncated too, not BadMagic.
        assert!(matches!(
            from_bytes(&[]),
            Err(FileError::Truncated { needed: 8 })
        ));
        assert!(matches!(
            from_bytes(b"RTTM"),
            Err(FileError::Truncated { needed: 4 })
        ));
    }

    #[test]
    fn bad_magic_rejected() {
        let model = trained();
        let mut bytes = to_bytes(&model);
        bytes[0] = b'X';
        // CRC still matches the body, so magic check must fire.
        let body_len = bytes.len() - 4;
        let crc = crc32(&bytes[..body_len]).to_le_bytes();
        bytes[body_len..].copy_from_slice(&crc);
        assert!(matches!(from_bytes(&bytes), Err(FileError::BadMagic)));
    }

    #[test]
    fn file_io_roundtrip() {
        let model = trained();
        let path = std::env::temp_dir().join("rttm_test_model.rttm");
        save(&model, &path).unwrap();
        let (shape, instrs) = load(&path).unwrap();
        assert_eq!(shape.classes, 3);
        assert_eq!(instrs.len(), isa::instruction_count(&model));
        std::fs::remove_file(&path).ok();

        let named = std::env::temp_dir().join("rttm_test_model_named.rttm");
        save_named(&model, "edge-7", &named).unwrap();
        let (_, _, tag) = load_full(&named).unwrap();
        assert_eq!(tag.unwrap().name, "edge-7");
        std::fs::remove_file(&named).ok();
    }

    // Regression: `name.len() as u16` used to truncate silently for
    // names past 65535 bytes, sealing a CRC-valid file whose declared
    // name length disagreed with the bytes that followed — unreadable
    // on load, undetectable at save.  Both writers must now refuse
    // before touching the filesystem.
    #[test]
    fn oversized_shape_name_rejected_at_save() {
        let mut model = trained();
        model.shape.name = "x".repeat(MAX_NAME_LEN + 1);
        let path = std::env::temp_dir().join("rttm_test_name_too_long.rttm");
        std::fs::remove_file(&path).ok();
        let err = save(&model, &path).unwrap_err();
        assert!(
            matches!(err, FileError::NameTooLong { field: "shape", len } if len == MAX_NAME_LEN + 1),
            "got {err:?}"
        );
        assert!(!path.exists(), "no file may be created for a rejected save");

        // The longest legal name still round-trips.
        model.shape.name = "y".repeat(MAX_NAME_LEN);
        save(&model, &path).unwrap();
        let (shape, _) = load(&path).unwrap();
        assert_eq!(shape.name.len(), MAX_NAME_LEN);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn oversized_deploy_name_rejected_at_save_named() {
        let model = trained();
        let path = std::env::temp_dir().join("rttm_test_deploy_too_long.rttm");
        std::fs::remove_file(&path).ok();
        let long = "d".repeat(MAX_NAME_LEN + 1);
        let err = save_named(&model, &long, &path).unwrap_err();
        assert!(
            matches!(err, FileError::NameTooLong { field: "deployment", len } if len == MAX_NAME_LEN + 1),
            "got {err:?}"
        );
        assert!(!path.exists(), "no file may be created for a rejected save");

        // save_named guards the shape name too (it frames both).
        let mut bad_shape = trained();
        bad_shape.shape.name = "x".repeat(MAX_NAME_LEN + 1);
        assert!(matches!(
            save_named(&bad_shape, "ok", &path),
            Err(FileError::NameTooLong { field: "shape", .. })
        ));
        assert!(!path.exists());
    }

    #[test]
    fn crc32_known_answer() {
        // IEEE CRC-32 of "123456789".
        assert_eq!(crc32(b"123456789"), 0xCBF43926);
    }

    #[test]
    fn fnv1a64_known_answers() {
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a64(b"a"), 0xaf63_dc4c_8601_ec8c);
    }
}
