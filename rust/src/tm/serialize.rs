//! `.rttm` model files: the portable artifact the Model Training Node
//! hands to deployments (and what a field tool would flash over the
//! network).  Contains the shape and the *compressed instruction
//! stream* — the dense model is redundant (paper §2: includes are the
//! model).
//!
//! Layout (little endian):
//! ```text
//! magic   "RTTM"            4 B
//! version u16               (currently 1)
//! name    u16 len + bytes
//! features/classes/clauses  u32 x 3
//! T       i32
//! s_milli u32               (s * 1000, fixed point)
//! count   u32               instruction count
//! instrs  count x u16
//! crc32   u32               over everything above
//! ```

use crate::config::TMShape;
use crate::isa::{self, Instr};
use crate::tm::model::TMModel;
use std::io::{Read, Write};

const MAGIC: &[u8; 4] = b"RTTM";
const VERSION: u16 = 1;

/// Errors loading a model file.
#[derive(Debug, thiserror::Error)]
pub enum FileError {
    #[error("io: {0}")]
    Io(#[from] std::io::Error),
    #[error("not an RTTM file")]
    BadMagic,
    /// The file ends before a declared field does.  Distinct from
    /// [`FileError::BadMagic`]: an adversarial file can be CRC-valid
    /// yet *claim* more payload than it carries.
    #[error("truncated file: {needed} more bytes required")]
    Truncated { needed: usize },
    /// The file carries MORE payload than its fields declare (e.g. a
    /// CRC-resealed `count` understated by one).  The inverse of
    /// [`FileError::Truncated`]: undeclared bytes are never silently
    /// ignored — they would be an unauthenticated side channel.
    #[error("malformed file: {extra} undeclared trailing bytes")]
    TrailingBytes { extra: usize },
    #[error("unsupported version {0}")]
    BadVersion(u16),
    #[error("checksum mismatch (corrupted file)")]
    BadCrc,
    #[error("malformed stream: {0}")]
    BadStream(#[from] isa::IsaError),
}

/// CRC-32 (IEEE, bitwise — cold path, no table needed).
pub fn crc32(data: &[u8]) -> u32 {
    let mut crc = 0xFFFF_FFFFu32;
    for &b in data {
        crc ^= b as u32;
        for _ in 0..8 {
            let mask = (crc & 1).wrapping_neg();
            crc = (crc >> 1) ^ (0xEDB8_8320 & mask);
        }
    }
    !crc
}

fn put_u16(buf: &mut Vec<u8>, v: u16) {
    buf.extend_from_slice(&v.to_le_bytes());
}
fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

/// Serialize a model (shape + compressed stream) to bytes.
pub fn to_bytes(model: &TMModel) -> Vec<u8> {
    let instrs = isa::encode(model);
    let mut buf = Vec::with_capacity(32 + model.shape.name.len() + 2 * instrs.len());
    buf.extend_from_slice(MAGIC);
    put_u16(&mut buf, VERSION);
    put_u16(&mut buf, model.shape.name.len() as u16);
    buf.extend_from_slice(model.shape.name.as_bytes());
    put_u32(&mut buf, model.shape.features as u32);
    put_u32(&mut buf, model.shape.classes as u32);
    put_u32(&mut buf, model.shape.clauses as u32);
    buf.extend_from_slice(&model.shape.t.to_le_bytes());
    put_u32(&mut buf, (model.shape.s * 1000.0).round() as u32);
    put_u32(&mut buf, instrs.len() as u32);
    for i in &instrs {
        put_u16(&mut buf, i.0);
    }
    let crc = crc32(&buf);
    put_u32(&mut buf, crc);
    buf
}

struct Cursor<'a> {
    data: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8], FileError> {
        if self.pos + n > self.data.len() {
            return Err(FileError::Truncated { needed: self.pos + n - self.data.len() });
        }
        let s = &self.data[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }
    fn u16(&mut self) -> Result<u16, FileError> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }
    fn u32(&mut self) -> Result<u32, FileError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn i32(&mut self) -> Result<i32, FileError> {
        Ok(i32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
}

/// Parse bytes back into (shape, instruction stream), verifying CRC and
/// stream well-formedness.
pub fn from_bytes(data: &[u8]) -> Result<(TMShape, Vec<Instr>), FileError> {
    // Minimum framing: magic + at least the CRC trailer.
    if data.len() < 8 {
        return Err(FileError::Truncated { needed: 8 - data.len() });
    }
    let (body, crc_bytes) = data.split_at(data.len() - 4);
    let stored = u32::from_le_bytes(crc_bytes.try_into().unwrap());
    if crc32(body) != stored {
        return Err(FileError::BadCrc);
    }
    let mut c = Cursor { data: body, pos: 0 };
    if c.take(4)? != MAGIC {
        return Err(FileError::BadMagic);
    }
    let version = c.u16()?;
    if version != VERSION {
        return Err(FileError::BadVersion(version));
    }
    let name_len = c.u16()? as usize;
    let name = String::from_utf8_lossy(c.take(name_len)?).into_owned();
    let features = c.u32()? as usize;
    let classes = c.u32()? as usize;
    let clauses = c.u32()? as usize;
    let t = c.i32()?;
    let s = c.u32()? as f64 / 1000.0;
    let count = c.u32()? as usize;
    // Validate the declared count against the bytes actually remaining
    // BEFORE sizing any allocation: a CRC-valid adversarial file
    // claiming `count = u32::MAX` would otherwise pre-allocate ~8 GB.
    let remaining = c.data.len() - c.pos;
    if count.saturating_mul(2) > remaining {
        return Err(FileError::Truncated {
            needed: count.saturating_mul(2) - remaining,
        });
    }
    let mut instrs = Vec::with_capacity(count);
    for _ in 0..count {
        instrs.push(Instr(c.u16()?));
    }
    // Every body byte must be declared by some field: leftover bytes
    // mean the count understates the stream (or the file smuggles
    // undeclared payload past the field layout).
    if c.pos != c.data.len() {
        return Err(FileError::TrailingBytes { extra: c.data.len() - c.pos });
    }
    let shape = TMShape {
        name,
        features,
        classes,
        clauses,
        t,
        s,
        train_batch: 32,
        n_states: 128,
    };
    // Validate the stream decodes within this shape.
    isa::encoder::decode_clauses(&instrs, shape.literals(), shape.classes)?;
    Ok((shape, instrs))
}

/// Write a model file.
pub fn save(model: &TMModel, path: impl AsRef<std::path::Path>) -> Result<(), FileError> {
    let mut f = std::fs::File::create(path)?;
    f.write_all(&to_bytes(model))?;
    Ok(())
}

/// Read a model file.
pub fn load(path: impl AsRef<std::path::Path>) -> Result<(TMShape, Vec<Instr>), FileError> {
    let mut data = Vec::new();
    std::fs::File::open(path)?.read_to_end(&mut data)?;
    from_bytes(&data)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::synth::SynthSpec;

    fn trained() -> TMModel {
        let shape = TMShape::synthetic(10, 3, 6);
        let data = SynthSpec::new(10, 3, 128).noise(0.05).seed(4).generate();
        crate::trainer::train_model(&shape, &data, 3, 2)
    }

    #[test]
    fn roundtrip_preserves_stream_and_shape() {
        let model = trained();
        let bytes = to_bytes(&model);
        let (shape, instrs) = from_bytes(&bytes).unwrap();
        assert_eq!(shape.features, model.shape.features);
        assert_eq!(shape.classes, model.shape.classes);
        assert_eq!(shape.clauses, model.shape.clauses);
        assert_eq!(shape.t, model.shape.t);
        assert!((shape.s - model.shape.s).abs() < 1e-3);
        assert_eq!(instrs, isa::encode(&model));
    }

    #[test]
    fn crc_catches_corruption() {
        let model = trained();
        let mut bytes = to_bytes(&model);
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x40;
        assert!(matches!(from_bytes(&bytes), Err(FileError::BadCrc)));
    }

    #[test]
    fn truncation_rejected() {
        let model = trained();
        let bytes = to_bytes(&model);
        assert!(from_bytes(&bytes[..bytes.len() / 2]).is_err());
        assert!(from_bytes(&[]).is_err());
    }

    /// Recompute and overwrite the CRC trailer so a tampered body is
    /// CRC-valid again (what an adversary controlling the file does).
    fn reseal(bytes: &mut [u8]) {
        let body = bytes.len() - 4;
        let crc = crc32(&bytes[..body]).to_le_bytes();
        bytes[body..].copy_from_slice(&crc);
    }

    #[test]
    fn adversarial_count_rejected_before_allocation() {
        let model = trained();
        let mut bytes = to_bytes(&model);
        // Offset of the `count` field: magic(4) + version(2) +
        // name_len(2) + name + 3 x u32 + i32 + u32.
        let off = 4 + 2 + 2 + model.shape.name.len() + 12 + 4 + 4;
        bytes[off..off + 4].copy_from_slice(&u32::MAX.to_le_bytes());
        reseal(&mut bytes);
        // Must fail as Truncated (count vs. remaining bytes), and fast —
        // no multi-GB Vec::with_capacity.
        assert!(matches!(
            from_bytes(&bytes),
            Err(FileError::Truncated { .. })
        ));

        // An off-by-one inflation is caught the same way.
        let mut bytes = to_bytes(&model);
        let count = u32::from_le_bytes(bytes[off..off + 4].try_into().unwrap());
        bytes[off..off + 4].copy_from_slice(&(count + 1).to_le_bytes());
        reseal(&mut bytes);
        assert!(matches!(
            from_bytes(&bytes),
            Err(FileError::Truncated { needed: 2 })
        ));

        // An off-by-one UNDERstatement leaves 2 undeclared body bytes:
        // rejected as TrailingBytes, never silently ignored.
        let mut bytes = to_bytes(&model);
        bytes[off..off + 4].copy_from_slice(&(count - 1).to_le_bytes());
        reseal(&mut bytes);
        assert!(matches!(
            from_bytes(&bytes),
            Err(FileError::TrailingBytes { extra: 2 })
        ));
    }

    #[test]
    fn truncation_mid_header_is_truncated_not_bad_magic() {
        let model = trained();
        let bytes = to_bytes(&model);
        // Cut inside the name field and re-seal the CRC: the only
        // remaining signal is the cursor running out of bytes, which
        // used to masquerade as BadMagic.
        let mut cut = bytes[..10].to_vec();
        cut.extend_from_slice(&crc32(&cut).to_le_bytes());
        assert!(matches!(from_bytes(&cut), Err(FileError::Truncated { .. })));
        // Sub-minimum files are truncated too, not BadMagic.
        assert!(matches!(
            from_bytes(&[]),
            Err(FileError::Truncated { needed: 8 })
        ));
        assert!(matches!(
            from_bytes(b"RTTM"),
            Err(FileError::Truncated { needed: 4 })
        ));
    }

    #[test]
    fn bad_magic_rejected() {
        let model = trained();
        let mut bytes = to_bytes(&model);
        bytes[0] = b'X';
        // CRC still matches the body, so magic check must fire.
        let body_len = bytes.len() - 4;
        let crc = crc32(&bytes[..body_len]).to_le_bytes();
        bytes[body_len..].copy_from_slice(&crc);
        assert!(matches!(from_bytes(&bytes), Err(FileError::BadMagic)));
    }

    #[test]
    fn file_io_roundtrip() {
        let model = trained();
        let path = std::env::temp_dir().join("rttm_test_model.rttm");
        save(&model, &path).unwrap();
        let (shape, instrs) = load(&path).unwrap();
        assert_eq!(shape.classes, 3);
        assert_eq!(instrs.len(), isa::instruction_count(&model));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn crc32_known_answer() {
        // IEEE CRC-32 of "123456789".
        assert_eq!(crc32(b"123456789"), 0xCBF43926);
    }
}
