//! Booleanization: continuous sensor channels -> Boolean features.
//!
//! The paper (§1, Fig 2) booleanizes edge inputs before the TM sees them.
//! Two encoders, matching what MATADOR/REDRESS use for the evaluated
//! workloads:
//!
//! * [`ThresholdEncoder`] — 1 bit/channel (mean split), used for image
//!   pixels (MNIST-style).
//! * [`ThermometerEncoder`] — `bits` quantile thresholds per channel;
//!   feature b is 1 iff value >= threshold b.  Used for multivariate
//!   sensor data (EMG, HAR, gas, drives).

/// Per-channel quantile thermometer encoder fitted on training data.
#[derive(Debug, Clone)]
pub struct ThermometerEncoder {
    /// `thresholds[ch][b]`, ascending per channel.
    pub thresholds: Vec<Vec<f64>>,
    pub bits: usize,
}

impl ThermometerEncoder {
    /// Fit per-channel quantile thresholds on raw samples `[n][channels]`.
    pub fn fit(samples: &[Vec<f64>], bits: usize) -> Self {
        assert!(bits >= 1);
        assert!(!samples.is_empty());
        let channels = samples[0].len();
        let mut thresholds = Vec::with_capacity(channels);
        for ch in 0..channels {
            let mut vals: Vec<f64> = samples.iter().map(|s| s[ch]).collect();
            vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let t = (1..=bits)
                .map(|b| {
                    // Quantile b/(bits+1) keeps bit populations balanced.
                    let q = b as f64 / (bits as f64 + 1.0);
                    let pos = q * (vals.len() - 1) as f64;
                    let lo = pos.floor() as usize;
                    let hi = pos.ceil() as usize;
                    let frac = pos - lo as f64;
                    vals[lo] * (1.0 - frac) + vals[hi] * frac
                })
                .collect();
            thresholds.push(t);
        }
        ThermometerEncoder { thresholds, bits }
    }

    pub fn features_out(&self) -> usize {
        self.thresholds.len() * self.bits
    }

    /// Encode one sample: `channels * bits` Boolean features.
    pub fn encode(&self, sample: &[f64]) -> Vec<u8> {
        assert_eq!(sample.len(), self.thresholds.len());
        let mut out = Vec::with_capacity(self.features_out());
        for (v, ths) in sample.iter().zip(&self.thresholds) {
            for th in ths {
                out.push(u8::from(*v >= *th));
            }
        }
        out
    }
}

/// Mean-split threshold encoder: 1 bit per channel.
#[derive(Debug, Clone)]
pub struct ThresholdEncoder {
    pub means: Vec<f64>,
}

impl ThresholdEncoder {
    pub fn fit(samples: &[Vec<f64>]) -> Self {
        assert!(!samples.is_empty());
        let channels = samples[0].len();
        let mut means = vec![0.0; channels];
        for s in samples {
            for (m, v) in means.iter_mut().zip(s) {
                *m += v;
            }
        }
        for m in &mut means {
            *m /= samples.len() as f64;
        }
        ThresholdEncoder { means }
    }

    pub fn encode(&self, sample: &[f64]) -> Vec<u8> {
        sample
            .iter()
            .zip(&self.means)
            .map(|(v, m)| u8::from(v >= m))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ramp(n: usize) -> Vec<Vec<f64>> {
        (0..n).map(|i| vec![i as f64]).collect()
    }

    #[test]
    fn thermometer_monotone_in_value() {
        let enc = ThermometerEncoder::fit(&ramp(100), 4);
        let lo = enc.encode(&[0.0]);
        let hi = enc.encode(&[99.0]);
        assert_eq!(lo, vec![0, 0, 0, 0]);
        assert_eq!(hi, vec![1, 1, 1, 1]);
        // Thermometer property: once 0, all later bits 0.
        let mid = enc.encode(&[50.0]);
        let first_zero = mid.iter().position(|&b| b == 0).unwrap_or(4);
        assert!(mid[first_zero..].iter().all(|&b| b == 0));
    }

    #[test]
    fn thermometer_quantiles_balanced() {
        let enc = ThermometerEncoder::fit(&ramp(1000), 3);
        // Quantiles at 25/50/75% of a uniform ramp.
        let t = &enc.thresholds[0];
        assert!((t[0] - 249.75).abs() < 1.0);
        assert!((t[1] - 499.5).abs() < 1.0);
        assert!((t[2] - 749.25).abs() < 1.0);
    }

    #[test]
    fn thermometer_feature_count() {
        let samples = vec![vec![0.0, 1.0, 2.0]; 10];
        let enc = ThermometerEncoder::fit(&samples, 8);
        assert_eq!(enc.features_out(), 24);
        assert_eq!(enc.encode(&[0.0, 1.0, 2.0]).len(), 24);
    }

    #[test]
    fn threshold_mean_split() {
        let enc = ThresholdEncoder::fit(&ramp(10));
        assert_eq!(enc.encode(&[0.0]), vec![0]);
        assert_eq!(enc.encode(&[9.0]), vec![1]);
        assert_eq!(enc.encode(&[4.5]), vec![1]); // >= mean
    }
}
