//! PJRT runtime: load and execute the AOT-compiled JAX artifacts.
//!
//! This is the only bridge between the rust system and the L2/L1 compute
//! graphs.  Artifacts are HLO *text* (see `python/compile/aot.py` for
//! why), compiled once per shape at startup by the PJRT CPU client and
//! then executed from the coordinator's hot path — Python never runs at
//! request time.
//!
//! Two typed executables:
//! * [`InferExecutable`] — `tm_infer_<cfg>.hlo.txt`: the packed bitwise
//!   inference graph (Pallas clause kernel + class sums).  Used as the
//!   golden model the accelerator simulator is verified against, and as
//!   the training node's evaluation engine.
//! * [`TrainExecutable`] — `tm_train_<cfg>.hlo.txt`: one batch of vanilla
//!   TM feedback.  This is what the Model Training Node (Fig 8) runs.
//!
//! # Feature gating
//!
//! The `xla` crate is not part of the offline vendor set, so the real
//! executor needs BOTH the `pjrt` feature (the API surface) and the
//! `xla` feature (the backend; add the `xla` dependency to Cargo.toml
//! when enabling it).  Any other combination compiles an API-identical
//! stub whose entry point ([`Runtime::cpu`]) returns a descriptive
//! error — every caller already handles artifact absence, and the
//! native trainer/simulator paths are unaffected.  This split is what
//! lets CI run the test matrix with `--features pjrt` on a machine
//! that cannot build `xla`.

use crate::config::TMShape;
use crate::tm::model::TMModel;
use anyhow::Result;

/// Result of one packed-batch inference: per-class sums and argmax
/// predictions for 32 bit-sliced datapoints.
#[derive(Debug, Clone, PartialEq)]
pub struct InferOut {
    /// `[classes][32]`
    pub class_sums: Vec<Vec<i32>>,
    /// `[32]`
    pub preds: Vec<i32>,
}

/// Fresh TA states just below the Include boundary.
pub fn init_ta_states(shape: &TMShape, rng: &mut crate::datasets::synth::XorShift64Star) -> Vec<i32> {
    (0..shape.total_tas())
        .map(|_| shape.n_states - 1 - i32::from(rng.next_f64() < 0.5))
        .collect()
}

#[cfg(all(feature = "pjrt", feature = "xla"))]
mod imp {
    use super::{InferOut, Result, TMModel, TMShape};
    use crate::config::Manifest;
    use anyhow::Context;
    use std::path::Path;

    /// Shared PJRT CPU client.  Create once, clone freely (the underlying
    /// client is reference-counted by the xla crate).
    pub struct Runtime {
        client: xla::PjRtClient,
    }

    impl Runtime {
        pub fn cpu() -> Result<Self> {
            let client = xla::PjRtClient::cpu().map_err(wrap)?;
            Ok(Runtime { client })
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        fn compile(&self, hlo_path: &Path) -> Result<xla::PjRtLoadedExecutable> {
            let proto = xla::HloModuleProto::from_text_file(hlo_path)
                .map_err(wrap)
                .with_context(|| format!("parsing {}", hlo_path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            self.client.compile(&comp).map_err(wrap)
        }

        /// Load + compile the inference artifact for `cfg`.
        pub fn load_infer(&self, manifest: &Manifest, cfg: &str) -> Result<InferExecutable> {
            let entry = manifest.entry(cfg)?;
            let exe = self.compile(&manifest.infer_hlo_path(cfg)?)?;
            Ok(InferExecutable { exe, shape: entry.shape.clone() })
        }

        /// Load + compile the train-step artifact for `cfg`.
        pub fn load_train(&self, manifest: &Manifest, cfg: &str) -> Result<TrainExecutable> {
            let entry = manifest.entry(cfg)?;
            let exe = self.compile(&manifest.train_hlo_path(cfg)?)?;
            Ok(TrainExecutable { exe, shape: entry.shape.clone() })
        }
    }

    fn wrap(e: xla::Error) -> anyhow::Error {
        anyhow::anyhow!("xla: {e}")
    }

    /// Compiled packed-inference graph.
    pub struct InferExecutable {
        exe: xla::PjRtLoadedExecutable,
        pub shape: TMShape,
    }

    impl InferExecutable {
        /// Run one 32-datapoint bit-sliced batch.
        ///
        /// `inc_mask` is `u32[K*L]` row-major (0 / 0xFFFF_FFFF); `xs_packed`
        /// is `u32[L]`.
        pub fn infer_packed(&self, inc_mask: &[u32], xs_packed: &[u32]) -> Result<InferOut> {
            let k = self.shape.total_clauses();
            let l = self.shape.literals();
            anyhow::ensure!(inc_mask.len() == k * l, "inc_mask len {} != {}", inc_mask.len(), k * l);
            anyhow::ensure!(xs_packed.len() == l, "xs_packed len {} != {}", xs_packed.len(), l);
            let mask = xla::Literal::vec1(inc_mask)
                .reshape(&[k as i64, l as i64])
                .map_err(wrap)?;
            let xs = xla::Literal::vec1(xs_packed);
            let result = self.exe.execute::<xla::Literal>(&[mask, xs]).map_err(wrap)?[0][0]
                .to_literal_sync()
                .map_err(wrap)?;
            let (sums, preds) = result.to_tuple2().map_err(wrap)?;
            let flat: Vec<i32> = sums.to_vec().map_err(wrap)?;
            let class_sums = flat.chunks(32).map(|c| c.to_vec()).collect();
            let preds: Vec<i32> = preds.to_vec().map_err(wrap)?;
            Ok(InferOut { class_sums, preds })
        }

        /// Convenience: run a dense model over one batch of literal rows
        /// (<= 32 datapoints), returning predictions for the first
        /// `lits.len()` lanes.
        pub fn infer_rows(&self, model: &TMModel, lits: &[Vec<u8>]) -> Result<Vec<usize>> {
            let n = lits.len();
            anyhow::ensure!(n <= 32, "at most 32 datapoints per packed batch");
            let packed = crate::isa::pack_literals(lits);
            let out = self.infer_packed(&model.to_packed_mask(), &packed)?;
            Ok(out.preds[..n].iter().map(|&p| p as usize).collect())
        }
    }

    /// Compiled train-step graph (one batch of feedback).
    pub struct TrainExecutable {
        exe: xla::PjRtLoadedExecutable,
        pub shape: TMShape,
    }

    impl TrainExecutable {
        /// Apply one batch of feedback, returning the updated TA states.
        ///
        /// `ta_state` is `i32[M*C*L]` row-major; `x_lit` is `i32[B*L]` literal
        /// rows; `ys` class labels; `seed` two u32 words of PRNG key.
        pub fn step(
            &self,
            ta_state: &[i32],
            x_lit: &[i32],
            ys: &[i32],
            seed: [i32; 2],
        ) -> Result<Vec<i32>> {
            let (m, c, l, b) = (
                self.shape.classes,
                self.shape.clauses,
                self.shape.literals(),
                self.shape.train_batch,
            );
            anyhow::ensure!(ta_state.len() == m * c * l, "ta_state len");
            anyhow::ensure!(x_lit.len() == b * l, "x_lit len {} != {}", x_lit.len(), b * l);
            anyhow::ensure!(ys.len() == b, "ys len");
            let ta = xla::Literal::vec1(ta_state)
                .reshape(&[m as i64, c as i64, l as i64])
                .map_err(wrap)?;
            let x = xla::Literal::vec1(x_lit)
                .reshape(&[b as i64, l as i64])
                .map_err(wrap)?;
            let y = xla::Literal::vec1(ys);
            let s = xla::Literal::vec1(&seed[..]);
            let result = self.exe.execute::<xla::Literal>(&[ta, x, y, s]).map_err(wrap)?[0][0]
                .to_literal_sync()
                .map_err(wrap)?;
            let out = result.to_tuple1().map_err(wrap)?;
            out.to_vec().map_err(wrap)
        }
    }
}

#[cfg(not(all(feature = "pjrt", feature = "xla")))]
mod imp {
    use super::{InferOut, Result, TMModel, TMShape};
    use crate::config::Manifest;

    const MSG: &str = "PJRT executor not compiled in: it needs the `pjrt` AND `xla` features \
                       (the `xla` crate is not in the offline vendor set); use the native \
                       backend, or add the dependency and rebuild with `--features pjrt,xla`";

    /// Stub PJRT client: constructing it reports how to enable the real
    /// one.  Keeps every caller compiling (and failing gracefully at
    /// runtime) in offline builds.
    pub struct Runtime {
        _priv: (),
    }

    impl Runtime {
        pub fn cpu() -> Result<Self> {
            anyhow::bail!(MSG)
        }

        pub fn platform(&self) -> String {
            "pjrt-disabled".to_string()
        }

        pub fn load_infer(&self, _manifest: &Manifest, _cfg: &str) -> Result<InferExecutable> {
            anyhow::bail!(MSG)
        }

        pub fn load_train(&self, _manifest: &Manifest, _cfg: &str) -> Result<TrainExecutable> {
            anyhow::bail!(MSG)
        }
    }

    /// Stub of the compiled packed-inference graph (not constructible
    /// without the `pjrt` feature).
    pub struct InferExecutable {
        pub shape: TMShape,
    }

    impl InferExecutable {
        pub fn infer_packed(&self, _inc_mask: &[u32], _xs_packed: &[u32]) -> Result<InferOut> {
            anyhow::bail!(MSG)
        }

        pub fn infer_rows(&self, _model: &TMModel, _lits: &[Vec<u8>]) -> Result<Vec<usize>> {
            anyhow::bail!(MSG)
        }
    }

    /// Stub of the compiled train-step graph (not constructible without
    /// the `pjrt` feature).
    pub struct TrainExecutable {
        pub shape: TMShape,
    }

    impl TrainExecutable {
        pub fn step(
            &self,
            _ta_state: &[i32],
            _x_lit: &[i32],
            _ys: &[i32],
            _seed: [i32; 2],
        ) -> Result<Vec<i32>> {
            anyhow::bail!(MSG)
        }
    }
}

pub use imp::{InferExecutable, Runtime, TrainExecutable};

impl TrainExecutable {
    /// Train over a dataset for `epochs`, starting from fresh states.
    pub fn fit(&self, xs: &[Vec<u8>], ys: &[usize], epochs: usize, seed: u64) -> Result<Vec<i32>> {
        let b = self.shape.train_batch;
        let l = self.shape.literals();
        let mut rng = crate::datasets::synth::XorShift64Star::new(seed);
        let mut ta = init_ta_states(&self.shape, &mut rng);
        let mut step_id: i32 = 0;
        for _ in 0..epochs {
            for chunk in xs.chunks(b).zip(ys.chunks(b)) {
                let (cx, cy) = chunk;
                if cx.len() < b {
                    break; // drop ragged tail (static shapes)
                }
                let mut x_lit = Vec::with_capacity(b * l);
                for row in cx {
                    let lits = crate::tm::reference::literals_from_features(row);
                    x_lit.extend(lits.iter().map(|&v| v as i32));
                }
                let ysb: Vec<i32> = cy.iter().map(|&y| y as i32).collect();
                ta = self.step(&ta, &x_lit, &ysb, [seed as i32, step_id])?;
                step_id += 1;
            }
        }
        Ok(ta)
    }

    pub fn model_from_states(&self, ta: &[i32]) -> TMModel {
        TMModel::from_ta_states(self.shape.clone(), ta)
    }
}
