//! MATADOR [18]: the model-specific synthesized accelerator baseline.
//!
//! MATADOR converts the Include-only clause expressions of a *specific*
//! trained model directly into LUT logic: every clause is a synthesized
//! AND tree, all clauses evaluate in parallel, and the class-sum adder
//! trees are pipelined — one inference per clock at 50 MHz after a short
//! fill.  That makes it the fastest and (per LUT) tightest TM
//! accelerator, at the price the paper's whole argument turns on: any
//! model/task change requires resynthesis and a new bitstream.
//!
//! Analytical model, anchored to Table 1's published builds:
//!
//! * LUTs ~ includes/2 (a LUT6 absorbs ~2 included literals of an AND
//!   tree) + adder-tree overhead ~ classes * clauses * 0.7 — fitted to
//!   the MNIST row (8709 LUTs, ~17k includes); CIFAR/KWS check rows.
//! * Pipeline depth = ceil(log2(max clause width)) + ceil(log2 clauses)
//!   + 3 (booleanize/argmax stages).
//! * Single-datapoint latency = depth cycles @ 50 MHz; steady-state
//!   throughput = 50M inf/s (II=1).  No batch mode (Fig 9 note).

use crate::tm::model::TMModel;

/// Table 1 anchor rows (chip, LUTs, FFs, BRAMs, freq).
pub const TABLE1_MATADOR: [(&str, u32, u32, u32, f64); 3] = [
    ("cifar2", 3867, 33212, 3, 50.0),
    ("kws6", 6063, 10658, 3, 50.0),
    ("mnist", 8709, 17440, 3, 50.0),
];

/// A synthesized (fixed-function) MATADOR build for one model.
#[derive(Debug, Clone)]
pub struct Matador {
    pub model_name: String,
    pub includes: usize,
    pub classes: usize,
    pub clauses: usize,
    pub pipeline_depth: u32,
    pub freq_mhz: f64,
}

impl Matador {
    /// "Synthesize" the accelerator for a trained model.
    pub fn synthesize(model: &TMModel) -> Self {
        let includes = model.include_count();
        let max_clause_width = (0..model.shape.classes)
            .flat_map(|m| (0..model.shape.clauses).map(move |c| (m, c)))
            .map(|(m, c)| model.clause_includes(m, c).len())
            .max()
            .unwrap_or(1)
            .max(1);
        let depth = (max_clause_width as f64).log2().ceil() as u32
            + (model.shape.clauses as f64).log2().ceil() as u32
            + 3;
        Matador {
            model_name: model.shape.name.clone(),
            includes,
            classes: model.shape.classes,
            clauses: model.shape.clauses,
            pipeline_depth: depth,
            freq_mhz: 50.0,
        }
    }

    /// LUT estimate (fitted to the Table 1 MNIST anchor).
    pub fn luts(&self) -> u32 {
        (self.includes as f64 / 2.0
            + self.classes as f64 * self.clauses as f64 * 0.7) as u32
    }

    /// FF estimate: pipeline registers across the adder trees.
    pub fn ffs(&self) -> u32 {
        (self.classes as f64 * self.clauses as f64 * 1.2
            + self.includes as f64 * 0.8) as u32
    }

    /// MATADOR streams inputs through AXI DMA; model weights are logic,
    /// so BRAM stays minimal (Table 1: 3 blocks for all builds).
    pub fn brams(&self) -> u32 {
        3
    }

    /// Latency for ONE datapoint in microseconds (pipeline fill).
    pub fn single_latency_us(&self) -> f64 {
        self.pipeline_depth as f64 / self.freq_mhz
    }

    /// Steady-state throughput (II = 1).
    pub fn throughput(&self) -> f64 {
        self.freq_mhz * 1e6
    }

    /// Energy per single inference, in microjoules.
    pub fn single_energy_uj(&self) -> f64 {
        crate::model_cost::energy::P_MATADOR_W * self.single_latency_us()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::synth::SynthSpec;
    use crate::TMShape;

    fn mnist_like_model(target_includes: usize) -> TMModel {
        // Deterministically sprinkle includes at MNIST dims.
        let shape = TMShape {
            name: "mnist".into(),
            features: 784,
            classes: 10,
            clauses: 200,
            t: 50,
            s: 10.0,
            train_batch: 32,
            n_states: 128,
        };
        let mut m = TMModel::empty(shape);
        let mut placed = 0usize;
        let mut rng = crate::datasets::synth::XorShift64Star::new(3);
        while placed < target_includes {
            let class = rng.below(10) as usize;
            let clause = rng.below(200) as usize;
            let lit = rng.below(1568) as usize;
            if !m.include(class, clause, lit) {
                m.set_include(class, clause, lit, true);
                placed += 1;
            }
        }
        m
    }

    #[test]
    fn mnist_scale_luts_near_table1_anchor() {
        // Paper §2: MNIST has ~17k includes of 3.1M TAs; Table 1 MATADOR
        // MNIST row is 8709 LUTs.  The fit must land within 15%.
        let m = mnist_like_model(17_000);
        let acc = Matador::synthesize(&m);
        let luts = acc.luts() as f64;
        assert!(
            (luts - 8709.0).abs() / 8709.0 < 0.15,
            "LUT fit off: {luts} vs 8709"
        );
    }

    #[test]
    fn single_latency_sub_microsecond() {
        // A pipelined fixed-function build: ~10-20 cycles @ 50 MHz.
        let m = mnist_like_model(17_000);
        let acc = Matador::synthesize(&m);
        let lat = acc.single_latency_us();
        assert!(lat < 1.0 && lat > 0.05, "latency {lat}");
    }

    #[test]
    fn no_batch_mode_throughput_is_clock_limited() {
        let m = mnist_like_model(1000);
        let acc = Matador::synthesize(&m);
        assert_eq!(acc.throughput(), 50e6);
    }

    #[test]
    fn more_includes_more_luts() {
        let small = Matador::synthesize(&mnist_like_model(2000));
        let big = Matador::synthesize(&mnist_like_model(20_000));
        assert!(big.luts() > small.luts());
    }

    #[test]
    fn synthesized_for_trained_model() {
        let shape = TMShape::synthetic(12, 3, 8);
        let data = SynthSpec::new(12, 3, 128).noise(0.05).seed(5).generate();
        let model = crate::trainer::train_model(&shape, &data, 3, 1);
        let acc = Matador::synthesize(&model);
        assert_eq!(acc.includes, model.include_count());
        assert!(acc.pipeline_depth >= 4);
    }
}
