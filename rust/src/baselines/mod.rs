//! The paper's comparators.
//!
//! * [`mcu`] — low-power microcontrollers running the *same* compressed
//!   Include-instruction inference as software (§4 Q2: ESP32; Fig 9:
//!   STM32Disco "RDRS" [15]).  Functional semantics are bit-identical
//!   (the software walk IS `isa::decode_infer`); timing/energy come
//!   from calibrated per-instruction cost models.
//! * [`matador`] — the model-specific synthesized FPGA flow [18]
//!   (§4 Q1): fully-pipelined clause logic, fastest TM accelerator, but
//!   fixed at synthesis time — the paper's flexibility foil.

pub mod matador;
pub mod mcu;

pub use matador::Matador;
pub use mcu::{Mcu, McuKind};
