//! MCU software baselines: the REDRESS-style compressed-model
//! interpreter on low-power microcontrollers.
//!
//! The MCU executes the identical instruction stream the accelerator
//! runs, but sequentially in software, one datapoint at a time (the
//! paper's ESP32 rows scale exactly 32x from single to batch — no
//! bit-slicing).  Functional output therefore reuses
//! [`crate::isa::decode_infer`]; the *cost model* is cycles per
//! instruction executed:
//!
//! ```text
//! cycles = instrs * cpi + features * load_cpf (feature staging)
//! latency = cycles / f;  energy = P * latency
//! ```
//!
//! Calibration (EXPERIMENTS.md §Calibration): the paper's Table 2
//! speedups (58x-684x vs Base) bracket a per-instruction software cost
//! of ~15-25 cycles on the ESP32 at 240 MHz once the 32x batch effect
//! and the 200/240 clock ratio are factored out; we use 20.  The STM32
//! Disco (RDRS, 216 MHz) uses 17 — REDRESS reports a hand-optimized
//! inner loop.

use crate::isa::{self, Instr, IsaError};
use crate::model_cost::energy::{P_ESP32_W, P_STM32_W};

/// Which microcontroller.
#[derive(Debug, Copy, Clone, PartialEq, Eq)]
pub enum McuKind {
    /// Espressif ESP32 (Table 2 comparator).
    Esp32,
    /// STM32F746 Discovery running REDRESS ("RDRS" in Fig 9).
    Stm32Disco,
}

impl McuKind {
    pub fn name(self) -> &'static str {
        match self {
            McuKind::Esp32 => "ESP32",
            McuKind::Stm32Disco => "STM32Disco(RDRS)",
        }
    }
    pub fn freq_mhz(self) -> f64 {
        match self {
            McuKind::Esp32 => 240.0,
            McuKind::Stm32Disco => 216.0,
        }
    }
    /// Average CPU cycles per compressed instruction interpreted.
    pub fn cycles_per_instr(self) -> f64 {
        match self {
            McuKind::Esp32 => 20.0,
            McuKind::Stm32Disco => 17.0,
        }
    }
    /// Cycles per Boolean feature staged into RAM per datapoint.
    pub fn cycles_per_feature(self) -> f64 {
        2.0
    }
    pub fn power_w(self) -> f64 {
        match self {
            McuKind::Esp32 => P_ESP32_W,
            McuKind::Stm32Disco => P_STM32_W,
        }
    }
}

/// An MCU programmed with a compressed model.
pub struct Mcu {
    pub kind: McuKind,
    pub instrs: Vec<Instr>,
    pub classes: usize,
    pub features: usize,
}

impl Mcu {
    pub fn new(kind: McuKind, instrs: Vec<Instr>, classes: usize, features: usize) -> Self {
        Mcu { kind, instrs, classes, features }
    }

    pub fn program_model(kind: McuKind, model: &crate::tm::model::TMModel) -> Self {
        Self::new(
            kind,
            isa::encode(model),
            model.shape.classes,
            model.shape.features,
        )
    }

    /// Classify one datapoint (features, not literals) — the exact
    /// software walk REDRESS runs.
    pub fn classify(&self, features: &[u8]) -> Result<usize, IsaError> {
        let lits = crate::tm::reference::literals_from_features(features);
        let sums = isa::decode_infer(&self.instrs, &lits, self.classes)?;
        Ok(crate::tm::reference::argmax(&sums))
    }

    /// Latency for ONE datapoint, in microseconds (cost model).
    pub fn single_latency_us(&self) -> f64 {
        let cycles = self.instrs.len() as f64 * self.kind.cycles_per_instr()
            + self.features as f64 * self.kind.cycles_per_feature();
        cycles / self.kind.freq_mhz()
    }

    /// Latency for a batch of `n` datapoints: strictly sequential
    /// (the paper's MCU rows are exactly 32x the single-datapoint
    /// latency).
    pub fn batch_latency_us(&self, n: usize) -> f64 {
        self.single_latency_us() * n as f64
    }

    /// Energy for a batch of `n`, in microjoules.
    pub fn batch_energy_uj(&self, n: usize) -> f64 {
        self.kind.power_w() * self.batch_latency_us(n)
    }

    /// Throughput in inferences/second.
    pub fn throughput(&self) -> f64 {
        1e6 / self.single_latency_us()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::synth::SynthSpec;
    use crate::tm::reference;
    use crate::TMShape;

    fn trained() -> (crate::tm::model::TMModel, crate::datasets::synth::Dataset) {
        let shape = TMShape::synthetic(12, 3, 8);
        let data = SynthSpec::new(12, 3, 256).noise(0.05).seed(4).generate();
        (crate::trainer::train_model(&shape, &data, 4, 9), data)
    }

    #[test]
    fn mcu_classification_matches_dense_reference() {
        let (model, data) = trained();
        let mcu = Mcu::program_model(McuKind::Esp32, &model);
        for x in &data.xs[..40] {
            let lits = reference::literals_from_features(x);
            assert_eq!(mcu.classify(x).unwrap(), reference::predict_dense(&model, &lits));
        }
    }

    #[test]
    fn batch_is_exactly_sequential() {
        // The paper's Table 2 scaling: batch = 32 x single.
        let (model, _) = trained();
        let mcu = Mcu::program_model(McuKind::Esp32, &model);
        let s = mcu.single_latency_us();
        assert!((mcu.batch_latency_us(32) - 32.0 * s).abs() < 1e-9);
    }

    #[test]
    fn esp32_slower_than_stm32_per_instr_but_both_slow() {
        let (model, _) = trained();
        let esp = Mcu::program_model(McuKind::Esp32, &model);
        let stm = Mcu::program_model(McuKind::Stm32Disco, &model);
        assert!(esp.single_latency_us() > 0.0);
        assert!(stm.single_latency_us() > 0.0);
        // Same instruction stream on both.
        assert_eq!(esp.instrs.len(), stm.instrs.len());
    }

    #[test]
    fn energy_is_power_times_time() {
        let (model, _) = trained();
        let mcu = Mcu::program_model(McuKind::Esp32, &model);
        let e = mcu.batch_energy_uj(32);
        assert!((e - mcu.kind.power_w() * mcu.batch_latency_us(32)).abs() < 1e-9);
    }

    #[test]
    fn larger_model_higher_latency() {
        let shape = TMShape::synthetic(12, 3, 8);
        let data = SynthSpec::new(12, 3, 256).noise(0.05).seed(4).generate();
        let small = crate::trainer::train_model(&shape, &data, 1, 9);
        let big = crate::trainer::train_model(&shape, &data, 8, 9);
        let (m_small, m_big) = (
            Mcu::program_model(McuKind::Esp32, &small),
            Mcu::program_model(McuKind::Esp32, &big),
        );
        if m_big.instrs.len() > m_small.instrs.len() {
            assert!(m_big.single_latency_us() > m_small.single_latency_us());
        }
    }
}
