//! Output FIFO: classifications waiting for the downstream consumer
//! (Fig 4.6 "Output FIFO", filled with up to 32 classifications per
//! batch).  Overflow drops are counted — backpressure visibility for the
//! coordinator.

#[derive(Debug, Clone)]
pub struct OutputFifo {
    pub depth: usize,
    buf: std::collections::VecDeque<u8>,
    /// Classifications dropped because the FIFO was full.
    pub overflow_drops: u64,
}

impl OutputFifo {
    pub fn new(depth: usize) -> Self {
        OutputFifo {
            depth,
            buf: std::collections::VecDeque::with_capacity(depth),
            overflow_drops: 0,
        }
    }

    /// Push one classification; returns false (and counts a drop) when
    /// full.
    pub fn push(&mut self, class: u8) -> bool {
        if self.buf.len() == self.depth {
            self.overflow_drops += 1;
            return false;
        }
        self.buf.push_back(class);
        true
    }

    /// Push a whole batch (up to 32 classifications).
    pub fn push_batch(&mut self, classes: &[u8]) -> usize {
        classes.iter().filter(|&&c| self.push(c)).count()
    }

    pub fn pop(&mut self) -> Option<u8> {
        self.buf.pop_front()
    }

    /// Drain everything (the AXIS read-out).
    pub fn drain(&mut self) -> Vec<u8> {
        self.buf.drain(..).collect()
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }
    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_order_preserved() {
        let mut f = OutputFifo::new(4);
        f.push_batch(&[3, 1, 2]);
        assert_eq!(f.pop(), Some(3));
        assert_eq!(f.pop(), Some(1));
        assert_eq!(f.pop(), Some(2));
        assert_eq!(f.pop(), None);
    }

    #[test]
    fn overflow_counted_not_panicking() {
        let mut f = OutputFifo::new(2);
        let accepted = f.push_batch(&[1, 2, 3, 4]);
        assert_eq!(accepted, 2);
        assert_eq!(f.overflow_drops, 2);
        assert_eq!(f.len(), 2);
    }

    #[test]
    fn drain_empties() {
        let mut f = OutputFifo::new(8);
        f.push_batch(&[7, 8]);
        assert_eq!(f.drain(), vec![7, 8]);
        assert!(f.is_empty());
    }
}
