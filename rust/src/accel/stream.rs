//! The accelerator's programming/inference stream protocol (Fig 4.1-4.3).
//!
//! Everything reaches the accelerator as a stream of fixed-width words
//! (16, 32 or 64 bits — a deploy-time customization).  A stream begins
//! with a *header*:
//!
//! ```text
//! word 0 (any width W):
//!   bit W-1: NEW_STREAM — resets the accelerator state machine
//!   bit W-2: TYPE — 1: Include instructions follow (new model)
//!                   0: Boolean features follow (inference request)
//!   remaining bits: classes/clauses (instruction header) or
//!                   feature count (feature header), width-dependent
//! word 1:
//!   instruction count (instruction header) or number of 32-datapoint
//!   batches (feature header)
//! ```
//!
//! Payloads are packed little-end-first: 16-bit instructions at W/16 per
//! word; bit-sliced u32 feature words at W/32 per word (two stream words
//! per feature word at W=16).
//!
//! The narrow 16-bit header cannot describe every model (e.g. cifar2's
//! 300 clauses/class exceeds its 8-bit clause field) — encoding returns
//! an error, mirroring the real deploy-time trade-off of the paper's
//! header-width customization.

use crate::isa::Instr;

/// Deploy-time header/stream word width.
#[derive(Debug, Copy, Clone, PartialEq, Eq)]
pub enum HeaderWidth {
    W16,
    W32,
    W64,
}

impl HeaderWidth {
    pub fn bits(self) -> u32 {
        match self {
            HeaderWidth::W16 => 16,
            HeaderWidth::W32 => 32,
            HeaderWidth::W64 => 64,
        }
    }

    /// (classes bits, clauses bits) available in word 0.
    fn fields(self) -> (u32, u32) {
        match self {
            HeaderWidth::W16 => (6, 8),
            HeaderWidth::W32 => (8, 16),
            HeaderWidth::W64 => (16, 32),
        }
    }
}

/// A decoded stream header.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Header {
    /// A new model: `count` 16-bit instructions follow.
    Instructions { classes: usize, clauses: usize, count: usize },
    /// An inference request: `batches` bit-sliced 32-datapoint batches of
    /// `features` Boolean features each.
    Features { features: usize, batches: usize },
}

/// Protocol errors.
#[derive(Debug, PartialEq, Eq, thiserror::Error)]
pub enum StreamError {
    #[error("model field {field}={value} does not fit the {width}-bit header")]
    HeaderOverflow { field: &'static str, value: usize, width: u32 },
    #[error("stream truncated: expected {expected} more words")]
    Truncated { expected: usize },
    #[error("word {index} is not a header (NEW_STREAM bit clear)")]
    NotAHeader { index: usize },
}

/// Encoder/decoder for one configured width.
#[derive(Debug, Copy, Clone)]
pub struct StreamCodec {
    pub width: HeaderWidth,
}

impl StreamCodec {
    pub fn new(width: HeaderWidth) -> Self {
        StreamCodec { width }
    }

    fn check(&self, field: &'static str, value: usize, bits: u32) -> Result<(), StreamError> {
        if value >= (1usize << bits) {
            return Err(StreamError::HeaderOverflow { field, value, width: self.width.bits() });
        }
        Ok(())
    }

    /// Header words for a model programming stream.
    pub fn instruction_header(
        &self,
        classes: usize,
        clauses: usize,
        count: usize,
    ) -> Result<[u64; 2], StreamError> {
        let w = self.width.bits();
        let (cb, lb) = self.width.fields();
        self.check("classes", classes, cb)?;
        self.check("clauses", clauses, lb)?;
        self.check("instructions", count, w.min(32))?;
        let mut w0 = 1u64 << (w - 1); // NEW_STREAM
        w0 |= 1u64 << (w - 2); // TYPE = instructions
        w0 |= (classes as u64) << (w - 2 - cb);
        w0 |= (clauses as u64) << (w - 2 - cb - lb);
        Ok([w0, count as u64])
    }

    /// Header words for an inference stream.
    pub fn feature_header(
        &self,
        features: usize,
        batches: usize,
    ) -> Result<[u64; 2], StreamError> {
        let w = self.width.bits();
        self.check("features", features, w - 2)?;
        self.check("batches", batches, w.min(32))?;
        let mut w0 = 1u64 << (w - 1); // NEW_STREAM
                                      // TYPE bit stays 0 = features.
        w0 |= features as u64;
        Ok([w0, batches as u64])
    }

    fn decode_header(&self, w0: u64, w1: u64) -> Header {
        let w = self.width.bits();
        let (cb, lb) = self.width.fields();
        if w0 >> (w - 2) & 1 == 1 {
            let classes = (w0 >> (w - 2 - cb)) & ((1 << cb) - 1);
            let clauses = (w0 >> (w - 2 - cb - lb)) & ((1 << lb) - 1);
            Header::Instructions {
                classes: classes as usize,
                clauses: clauses as usize,
                count: w1 as usize,
            }
        } else {
            Header::Features {
                features: (w0 & ((1u64 << (w - 2)) - 1)) as usize,
                batches: w1 as usize,
            }
        }
    }

    /// Pack 16-bit instructions into stream words.
    pub fn pack_instructions(&self, instrs: &[Instr]) -> Vec<u64> {
        let per = (self.width.bits() / 16) as usize;
        instrs
            .chunks(per)
            .map(|chunk| {
                let mut w = 0u64;
                for (i, ins) in chunk.iter().enumerate() {
                    w |= (ins.0 as u64) << (16 * i);
                }
                w
            })
            .collect()
    }

    /// Unpack `count` instructions from stream words.
    pub fn unpack_instructions(&self, words: &[u64], count: usize) -> Vec<Instr> {
        let per = (self.width.bits() / 16) as usize;
        let mut out = Vec::with_capacity(count);
        'outer: for w in words {
            for i in 0..per {
                if out.len() == count {
                    break 'outer;
                }
                out.push(Instr((w >> (16 * i)) as u16));
            }
        }
        out
    }

    /// Pack bit-sliced u32 feature words into stream words.
    pub fn pack_feature_words(&self, feats: &[u32]) -> Vec<u64> {
        match self.width {
            HeaderWidth::W16 => feats
                .iter()
                .flat_map(|&f| [f as u64 & 0xFFFF, (f >> 16) as u64])
                .collect(),
            HeaderWidth::W32 => feats.iter().map(|&f| f as u64).collect(),
            HeaderWidth::W64 => feats
                .chunks(2)
                .map(|c| {
                    let mut w = c[0] as u64;
                    if let Some(&hi) = c.get(1) {
                        w |= (hi as u64) << 32;
                    }
                    w
                })
                .collect(),
        }
    }

    /// Unpack `count` bit-sliced u32 feature words.
    pub fn unpack_feature_words(&self, words: &[u64], count: usize) -> Vec<u32> {
        let mut out = Vec::with_capacity(count);
        match self.width {
            HeaderWidth::W16 => {
                for pair in words.chunks(2) {
                    if out.len() == count {
                        break;
                    }
                    let lo = pair[0] & 0xFFFF;
                    let hi = pair.get(1).copied().unwrap_or(0) & 0xFFFF;
                    out.push((lo | (hi << 16)) as u32);
                }
            }
            HeaderWidth::W32 => {
                for &w in words.iter().take(count) {
                    out.push(w as u32);
                }
            }
            HeaderWidth::W64 => {
                'outer: for &w in words {
                    for half in [w as u32, (w >> 32) as u32] {
                        if out.len() == count {
                            break 'outer;
                        }
                        out.push(half);
                    }
                }
            }
        }
        out.truncate(count);
        out
    }

    /// Stream-word count for a feature payload of `feature_words` u32s.
    pub fn feature_payload_len(&self, feature_words: usize) -> usize {
        match self.width {
            HeaderWidth::W16 => feature_words * 2,
            HeaderWidth::W32 => feature_words,
            HeaderWidth::W64 => feature_words.div_ceil(2),
        }
    }

    /// Stream-word count for an instruction payload.
    pub fn instruction_payload_len(&self, count: usize) -> usize {
        count.div_ceil((self.width.bits() / 16) as usize)
    }
}

/// A fully-decoded inbound message.
#[derive(Debug, Clone, PartialEq)]
pub enum Message {
    /// Program a new model.
    Program {
        classes: usize,
        clauses: usize,
        instrs: Vec<Instr>,
    },
    /// Run inference over bit-sliced batches (each `features` words).
    Infer { features: usize, batches: Vec<Vec<u32>> },
}

/// Decode a whole stream into messages.
///
/// The decoder is a word-countdown state machine, like the RTL: the
/// header announces its payload length and every following word is
/// *data* until the countdown expires (payload bits may freely alias the
/// NEW_STREAM bit — instruction P bits land there at W=32).  The
/// NEW_STREAM flag is therefore meaningful only where a header is legal;
/// a true mid-payload abort is the out-of-band reset line
/// ([`super::core::Core::reset`]).  Truncated tails error.
pub fn decode_stream(codec: &StreamCodec, words: &[u64]) -> Result<Vec<Message>, StreamError> {
    let w = codec.width.bits();
    let mut out = Vec::new();
    let mut i = 0usize;
    while i < words.len() {
        if words[i] >> (w - 1) & 1 != 1 {
            return Err(StreamError::NotAHeader { index: i });
        }
        if i + 1 >= words.len() {
            return Err(StreamError::Truncated { expected: 1 });
        }
        let header = codec.decode_header(words[i], words[i + 1]);
        i += 2;
        match header {
            Header::Instructions { classes, clauses, count } => {
                let payload = take_payload(words, &mut i, codec.instruction_payload_len(count))?;
                let instrs = codec.unpack_instructions(payload, count);
                out.push(Message::Program { classes, clauses, instrs });
            }
            Header::Features { features, batches } => {
                let per_batch = codec.feature_payload_len(features);
                let payload = take_payload(words, &mut i, per_batch * batches)?;
                let mut rows = Vec::with_capacity(batches);
                for b in 0..batches {
                    let chunk = &payload[b * per_batch..(b + 1) * per_batch];
                    rows.push(codec.unpack_feature_words(chunk, features));
                }
                out.push(Message::Infer { features, batches: rows });
            }
        }
    }
    Ok(out)
}

/// Consume exactly `need` payload words (countdown framing).
fn take_payload<'a>(
    words: &'a [u64],
    i: &mut usize,
    need: usize,
) -> Result<&'a [u64], StreamError> {
    let start = *i;
    if words.len() - start < need {
        return Err(StreamError::Truncated { expected: need - (words.len() - start) });
    }
    *i += need;
    Ok(&words[start..*i])
}

#[cfg(test)]
mod tests {
    use super::*;

    fn all_widths() -> [StreamCodec; 3] {
        [
            StreamCodec::new(HeaderWidth::W16),
            StreamCodec::new(HeaderWidth::W32),
            StreamCodec::new(HeaderWidth::W64),
        ]
    }

    #[test]
    fn instruction_header_roundtrip_all_widths() {
        for c in all_widths() {
            let [w0, w1] = c.instruction_header(10, 200, 17000).unwrap();
            assert_eq!(
                c.decode_header(w0, w1),
                Header::Instructions { classes: 10, clauses: 200, count: 17000 }
            );
        }
    }

    #[test]
    fn feature_header_roundtrip_all_widths() {
        for c in all_widths() {
            let [w0, w1] = c.feature_header(784, 12).unwrap();
            assert_eq!(c.decode_header(w0, w1), Header::Features { features: 784, batches: 12 });
        }
    }

    #[test]
    fn narrow_header_rejects_big_models() {
        // cifar2: 300 clauses/class exceeds the 16-bit header's 8-bit
        // clause field — a real deploy-time constraint.
        let c = StreamCodec::new(HeaderWidth::W16);
        assert_eq!(
            c.instruction_header(2, 300, 100),
            Err(StreamError::HeaderOverflow { field: "clauses", value: 300, width: 16 })
        );
        // ...but the 32-bit header accepts it.
        assert!(StreamCodec::new(HeaderWidth::W32).instruction_header(2, 300, 100).is_ok());
    }

    #[test]
    fn instruction_packing_roundtrip() {
        let instrs: Vec<Instr> = (0..7u16).map(|i| Instr(0x8000 | i * 321)).collect();
        for c in all_widths() {
            let words = c.pack_instructions(&instrs);
            assert_eq!(words.len(), c.instruction_payload_len(instrs.len()));
            let back = c.unpack_instructions(&words, instrs.len());
            assert_eq!(back, instrs);
        }
    }

    #[test]
    fn feature_packing_roundtrip() {
        let feats: Vec<u32> = (0..9).map(|i| 0xDEAD_0000u32.wrapping_add(i * 77)).collect();
        for c in all_widths() {
            let words = c.pack_feature_words(&feats);
            assert_eq!(words.len(), c.feature_payload_len(feats.len()));
            assert_eq!(c.unpack_feature_words(&words, feats.len()), feats);
        }
    }

    #[test]
    fn full_stream_roundtrip() {
        let c = StreamCodec::new(HeaderWidth::W32);
        let instrs: Vec<Instr> = (0..5u16).map(Instr).collect();
        let mut words = Vec::new();
        words.extend(c.instruction_header(3, 4, instrs.len()).unwrap());
        words.extend(c.pack_instructions(&instrs));
        let feats = vec![vec![1u32, 2, 3], vec![4, 5, 6]];
        words.extend(c.feature_header(3, 2).unwrap());
        for b in &feats {
            words.extend(c.pack_feature_words(b));
        }
        let msgs = decode_stream(&c, &words).unwrap();
        assert_eq!(msgs.len(), 2);
        assert_eq!(msgs[0], Message::Program { classes: 3, clauses: 4, instrs });
        assert_eq!(msgs[1], Message::Infer { features: 3, batches: feats });
    }

    #[test]
    fn payload_words_may_alias_header_bits() {
        // Countdown framing: instruction payload words whose top bit is
        // set (a negative-polarity instruction in the high half-word)
        // must be consumed as data, not misparsed as headers.
        let c = StreamCodec::new(HeaderWidth::W32);
        let instrs = vec![Instr(0x0001), Instr(0x8001)]; // second has P=1
        let mut words = Vec::new();
        words.extend(c.instruction_header(2, 2, 2).unwrap());
        words.extend(c.pack_instructions(&instrs));
        assert!(words[2] >> 31 & 1 == 1, "aliasing precondition");
        let msgs = decode_stream(&c, &words).unwrap();
        assert_eq!(msgs, vec![Message::Program { classes: 2, clauses: 2, instrs }]);
    }

    #[test]
    fn truncated_stream_errors() {
        let c = StreamCodec::new(HeaderWidth::W32);
        let mut words: Vec<u64> = c.instruction_header(2, 2, 8).unwrap().to_vec();
        words.push(1); // only 1 of 4 payload words
        assert_eq!(decode_stream(&c, &words), Err(StreamError::Truncated { expected: 3 }));
    }

    #[test]
    fn garbage_start_errors() {
        let c = StreamCodec::new(HeaderWidth::W32);
        assert_eq!(
            decode_stream(&c, &[0x1234]),
            Err(StreamError::NotAHeader { index: 0 })
        );
    }
}
