//! Cycle-accurate simulator of the paper's accelerator (Fig 4, Fig 5, Fig 7).
//!
//! The real artifact is RTL on an eFPGA; here the *microarchitecture* is
//! simulated exactly (instruction walk, memories, 32-wide bit-sliced
//! batch datapath, pipeline timing) and the physical quantities
//! (LUT/FF/BRAM/f_max/power) come from the calibrated models in
//! [`crate::model_cost`].  Latency = cycles / f; energy = P x latency —
//! the same arithmetic the paper's evaluation uses.
//!
//! * [`stream`] — the programming/inference stream protocol (Fig 4.1-4.3).
//! * [`memory`] — instruction/feature BRAM models (Fig 6 customization).
//! * [`core`] — the base inference core (Fig 4.4-4.6, Fig 5 timing).
//! * [`fifo`] — the classification output FIFO.
//! * [`multicore`] — the AXIS-connected multi-core build (Fig 7).
//! * [`engine`] — host-side batch scheduler for multi-batch, multi-core
//!   serving throughput.

pub mod axis;
pub mod core;
pub mod engine;
pub mod fifo;
pub mod memory;
pub mod multicore;
pub mod stream;

pub use self::core::{AccelConfig, BatchResult, Core, CycleStats, PipelineMode, SlicedKernel};
pub use self::engine::StreamStats;
pub use self::multicore::{MultiCore, ParallelMode};
