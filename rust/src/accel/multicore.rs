//! The AXIS-connected multi-core build (Fig 7).
//!
//! Each inference core is a base core; the AXIS splitter writes each
//! core's instruction memory with the instructions of a *non-overlapping
//! class range* but broadcasts the same features to every feature
//! memory.  Class-level parallelism: batch latency = slowest core +
//! merge.  The partitioner balances *instruction counts* (include
//! counts), not class counts — include-heavy classes dominate a core's
//! walk time.

use super::core::{
    argmax_lanes, argmax_rows, AccelConfig, BatchResult, Core, CoreError, SlicedKernel,
    SlicedResult,
};
use crate::isa::{self, SlicedBatch};
use crate::tm::model::TMModel;

/// How the HOST schedules the per-core walks.  The simulated cycle
/// model is identical either way (cores are parallel hardware; only
/// host wall-clock changes), and both paths produce byte-identical
/// results.
#[derive(Debug, Copy, Clone, PartialEq, Eq, Default)]
pub enum ParallelMode {
    /// Thread only when the scheduled work is large enough to amortize
    /// thread-spawn cost (see [`AUTO_THREAD_MIN_OPS`]).
    #[default]
    Auto,
    /// Always walk cores one after another on the calling thread.
    Serial,
    /// Always fan cores out across OS threads (std::thread::scope).
    Threads,
}

/// `Auto` threads once `heaviest-core instruction count x batches`
/// crosses this many instruction slots — roughly where the walk time
/// clears the ~tens-of-microseconds cost of spawning a thread per core.
pub const AUTO_THREAD_MIN_OPS: usize = 1 << 16;

/// A multi-core accelerator with class partitioning.
pub struct MultiCore {
    pub cores: Vec<Core>,
    /// Class ranges (contiguous) per core; `assign[i]` = (start, end).
    pub assign: Vec<(usize, usize)>,
    pub classes: usize,
    /// Host scheduling policy for `run_batch`/`run_batches`.
    pub parallel: ParallelMode,
    /// Transpose scratch of the sliced bulk path: the batch is packed
    /// ONCE here and broadcast to every core (the features are shared;
    /// only the class partition differs).
    sliced_batch: SlicedBatch,
    /// Per-core result scratch of the sliced path (local class ranges).
    per_core_sliced: Vec<SlicedResult>,
    /// Merged (global-class-order) result of the last sliced run.
    sliced_merged: MultiSlicedRun,
}

/// Merged result of a multi-core bit-sliced run — the per-row analog of
/// [`MultiBatchResult`]: global class sums gathered from the
/// class-partitioned cores, global argmax, parallel timing.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct MultiSlicedRun {
    /// Class-major per-row sums in GLOBAL class order:
    /// `class_sums[class * padded_rows + row]`.
    pub class_sums: Vec<i32>,
    pub padded_rows: usize,
    pub rows: usize,
    /// Global argmax per padded row.
    pub preds: Vec<u8>,
    /// Simulated cycles per 32-row batch: slowest core + merge (the
    /// cores are parallel hardware).
    pub batch_cycles: u64,
    /// 32-row batches of the equivalent 32-lane walk.
    pub batches: u64,
}

impl MultiSlicedRun {
    /// One row's sum for one (global) class.
    #[inline]
    pub fn class_sum(&self, class: usize, row: usize) -> i32 {
        self.class_sums[class * self.padded_rows + row]
    }

    /// Total simulated cycles of the run (all batches, parallel model).
    pub fn total_cycles(&self) -> u64 {
        self.batch_cycles * self.batches
    }
}

impl MultiCore {
    /// The paper's 5-core M configuration (Table 1/Table 2).
    pub fn five_core() -> Self {
        Self::new(5, AccelConfig::multicore_core())
    }

    pub fn new(n: usize, per_core: AccelConfig) -> Self {
        assert!(n >= 1);
        MultiCore {
            cores: (0..n).map(|_| Core::new(per_core.clone())).collect(),
            assign: Vec::new(),
            classes: 0,
            parallel: ParallelMode::Auto,
            sliced_batch: SlicedBatch::default(),
            per_core_sliced: Vec::new(),
            sliced_merged: MultiSlicedRun::default(),
        }
    }

    /// Set the host scheduling policy (builder style).
    pub fn with_parallel(mut self, p: ParallelMode) -> Self {
        self.parallel = p;
        self
    }

    pub fn n_cores(&self) -> usize {
        self.cores.len()
    }

    /// Balanced contiguous partition of classes by per-class instruction
    /// count (greedy block fill against the ideal share).
    pub fn partition(per_class_instrs: &[usize], n_cores: usize) -> Vec<(usize, usize)> {
        let classes = per_class_instrs.len();
        let n = n_cores.min(classes).max(1);
        let total: usize = per_class_instrs.iter().sum();
        let mut bounds = Vec::with_capacity(n);
        let mut start = 0usize;
        let mut cum = 0usize;
        for (c, &w) in per_class_instrs.iter().enumerate() {
            cum += w;
            let remaining_classes = classes - c - 1;
            let remaining_cores = n - bounds.len() - 1;
            // Close the current block once the cumulative weight crosses
            // this block's ideal boundary, but never leave fewer classes
            // than cores still to fill.
            let boundary = (total as f64) * (bounds.len() + 1) as f64 / n as f64;
            if bounds.len() < n - 1
                && (cum as f64 + 1e-9 >= boundary || remaining_classes == remaining_cores)
            {
                bounds.push((start, c + 1));
                start = c + 1;
            }
        }
        bounds.push((start, classes));
        debug_assert_eq!(bounds.len(), n);
        bounds
    }

    /// Program all cores from a dense model (the AXIS split of the
    /// instruction stream).
    pub fn program_model(&mut self, model: &TMModel) -> Result<(), CoreError> {
        let per_class = model
            .includes_per_class()
            .iter()
            .map(|&n| if n == 0 { 2 } else { n })
            .collect::<Vec<_>>();
        let assign = Self::partition(&per_class, self.cores.len());
        self.classes = model.shape.classes;
        for (core, &(s, e)) in self.cores.iter_mut().zip(&assign) {
            if s == e {
                // More cores than classes: leave idle.
                continue;
            }
            let slice = model.slice_classes(s..e);
            core.program_model(&slice)?;
        }
        self.assign = assign;
        Ok(())
    }

    /// Combined FNV-1a digest over every programmed core's derived
    /// program buffers (idle cores hash as absent) — see
    /// [`Core::program_digest`].  `None` until any core is programmed.
    pub fn program_digest(&self) -> Option<u64> {
        let mut d = crate::isa::ProgramDigest::new();
        let mut any = false;
        for core in &self.cores {
            match core.program_digest() {
                Some(h) => {
                    any = true;
                    d.byte(1);
                    d.u64(h);
                }
                None => d.byte(0),
            }
        }
        any.then(|| d.finish())
    }

    /// Fault injection across the split: flip `n_bits` seeded bits in
    /// ONE programmed core's derived buffers (seed picks the victim
    /// core deterministically).  Returns bits flipped.
    pub fn flip_program_bits(&mut self, seed: u64, n_bits: u32) -> u32 {
        let programmed: Vec<usize> = (0..self.cores.len())
            .filter(|&i| self.cores[i].is_programmed())
            .collect();
        if programmed.is_empty() {
            return 0;
        }
        let victim = programmed[(seed % programmed.len() as u64) as usize];
        self.cores[victim].flip_program_bits(seed, n_bits)
    }

    /// True when the current policy threads `batches` worth of work.
    fn use_threads(&self, batches: usize) -> bool {
        match self.parallel {
            ParallelMode::Serial => false,
            ParallelMode::Threads => self.cores.len() > 1,
            ParallelMode::Auto => {
                let heaviest = self
                    .cores
                    .iter()
                    .map(|c| c.instruction_count())
                    .max()
                    .unwrap_or(0);
                self.cores.len() > 1 && heaviest.saturating_mul(batches) >= AUTO_THREAD_MIN_OPS
            }
        }
    }

    /// Run one bit-sliced batch on all cores (features broadcast),
    /// merging class sums and taking the global argmax.
    ///
    /// Timing: cores run in parallel -> batch cycles = max over cores;
    /// the merge adds one cycle per class (sum gather) plus the argmax
    /// chain, modeled in `merge_cycles`.  Host scheduling follows
    /// [`Self::parallel`]; serial and threaded execution are
    /// byte-identical.
    pub fn run_batch(&mut self, packed_features: &[u32]) -> Result<MultiBatchResult, CoreError> {
        if self.use_threads(1) {
            self.run_batch_threaded(packed_features)
        } else {
            self.run_batch_serial(packed_features)
        }
    }

    /// Serial reference path: cores walk one after another on the
    /// calling thread.
    pub fn run_batch_serial(&mut self, packed_features: &[u32]) -> Result<MultiBatchResult, CoreError> {
        if self.assign.is_empty() {
            return Err(CoreError::NotProgrammed);
        }
        let mut per_core = Vec::with_capacity(self.cores.len());
        for (core, &(s, e)) in self.cores.iter_mut().zip(&self.assign) {
            if s == e {
                per_core.push(None);
                continue;
            }
            per_core.push(Some(core.run_batch(packed_features)?));
        }
        Ok(self.merge_batch(per_core))
    }

    /// Parallel serving path: every class-partitioned core walks the
    /// (broadcast) batch on its own OS thread — the host-side mirror of
    /// the Fig 7 class-level parallelism.
    pub fn run_batch_threaded(&mut self, packed_features: &[u32]) -> Result<MultiBatchResult, CoreError> {
        if self.assign.is_empty() {
            return Err(CoreError::NotProgrammed);
        }
        // `assign` can be shorter than `cores` (idle trailing cores);
        // slot count follows `assign` so serial and threaded results
        // have identical `per_core` shapes.
        let mut slots: Vec<Option<Result<BatchResult, CoreError>>> = Vec::new();
        slots.resize_with(self.assign.len(), || None);
        std::thread::scope(|scope| {
            for ((core, &(s, e)), slot) in self
                .cores
                .iter_mut()
                .zip(&self.assign)
                .zip(slots.iter_mut())
            {
                if s == e {
                    continue;
                }
                scope.spawn(move || {
                    *slot = Some(core.run_batch(packed_features));
                });
            }
        });
        let mut per_core = Vec::with_capacity(slots.len());
        for slot in slots {
            match slot {
                None => per_core.push(None),
                Some(Err(e)) => return Err(e),
                Some(Ok(r)) => per_core.push(Some(r)),
            }
        }
        Ok(self.merge_batch(per_core))
    }

    /// Execute a stream of batches.  Threaded scheduling spawns ONE
    /// thread per core for the whole stream, so the spawn cost is
    /// amortized across every batch — the multi-core serving hot path
    /// (used by [`crate::accel::engine`]).  On success, results are
    /// byte-identical to repeated [`Self::run_batch`] calls.
    ///
    /// Error semantics: the first failing core's error (in core order)
    /// is returned either way, but threaded scheduling cannot cancel
    /// sibling cores mid-stream, so after an `Err` the non-failing
    /// cores may have executed MORE batches (lifetime stats, FIFOs)
    /// than under serial scheduling, which stops at the failing batch.
    pub fn run_batches(&mut self, batches: &[&[u32]]) -> Result<Vec<MultiBatchResult>, CoreError> {
        if self.assign.is_empty() {
            return Err(CoreError::NotProgrammed);
        }
        if batches.is_empty() {
            return Ok(Vec::new());
        }
        if !self.use_threads(batches.len()) {
            return batches.iter().map(|&b| self.run_batch_serial(b)).collect();
        }
        let mut slots: Vec<Option<Result<Vec<BatchResult>, CoreError>>> = Vec::new();
        slots.resize_with(self.assign.len(), || None);
        std::thread::scope(|scope| {
            for ((core, &(s, e)), slot) in self
                .cores
                .iter_mut()
                .zip(&self.assign)
                .zip(slots.iter_mut())
            {
                if s == e {
                    continue;
                }
                scope.spawn(move || {
                    *slot = Some(core.run_batches(batches));
                });
            }
        });
        // Surface the first error in core order, then transpose the
        // per-core streams into per-batch merged results.
        let mut streams: Vec<Option<std::vec::IntoIter<BatchResult>>> =
            Vec::with_capacity(slots.len());
        for slot in slots {
            match slot {
                None => streams.push(None),
                Some(Err(e)) => return Err(e),
                Some(Ok(v)) => streams.push(Some(v.into_iter())),
            }
        }
        let mut out = Vec::with_capacity(batches.len());
        for _ in 0..batches.len() {
            let per_core: Vec<Option<BatchResult>> = streams
                .iter_mut()
                .map(|s| s.as_mut().map(|it| it.next().expect("one result per batch")))
                .collect();
            out.push(self.merge_batch(per_core));
        }
        Ok(out)
    }

    /// Bit-sliced bulk execution across the class-partitioned cores:
    /// the rows are transposed ONCE into 64-row literal planes
    /// (broadcast — every core reads the same planes, like the AXIS
    /// feature broadcast), each core runs the sliced kernel over its
    /// class range (on its own OS thread when the scheduling policy
    /// threads this much work), and per-row class sums are gathered
    /// into global order for the global argmax.  Chunking is the
    /// CALLER's job (`accel::engine` drives this in 64-row-aligned
    /// chunks); per-call scratch is owned by the engine and reused.
    ///
    /// Observable per-core state (lifetime counters, FIFOs) advances
    /// exactly as under [`Self::run_batches`] over the equivalent
    /// 32-row batches.  Error semantics mirror `run_batches`: the first
    /// failing core's error in core order, with the same
    /// threaded-siblings caveat.
    pub fn run_rows_sliced_ref(&mut self, rows: &[Vec<u8>]) -> Result<&MultiSlicedRun, CoreError> {
        self.run_rows_kernel_ref(rows, SlicedKernel::Sliced)
    }

    /// [`Self::run_rows_sliced_ref`] with an explicit bulk-kernel pick.
    /// `Auto` resolves PER CORE against each core's own derived include
    /// density — the kernels are byte-identical, so a mixed fleet (some
    /// cores compressed, some sliced) still merges exactly.
    pub fn run_rows_kernel_ref(
        &mut self,
        rows: &[Vec<u8>],
        kernel: SlicedKernel,
    ) -> Result<&MultiSlicedRun, CoreError> {
        if self.assign.is_empty() {
            return Err(CoreError::NotProgrammed);
        }
        if rows.is_empty() {
            return Err(CoreError::BadBatch { rows: 0, reason: "empty request" });
        }
        let mut batch = std::mem::take(&mut self.sliced_batch);
        isa::pack_literals_sliced_into(rows, &mut batch);
        let batches = rows.len().div_ceil(32);
        let run = self.run_sliced_cores(&batch, batches, kernel);
        self.sliced_batch = batch;
        run?;

        // Merge: gather local class ranges into global order, slowest
        // core + merge cycles, global argmax per row.
        let padded = self.sliced_batch.padded_rows();
        let merged = &mut self.sliced_merged;
        merged.rows = self.sliced_batch.rows;
        merged.padded_rows = padded;
        merged.batches = batches as u64;
        merged.class_sums.clear();
        merged.class_sums.resize(self.classes * padded, 0);
        let mut slowest = 0u64;
        for (out, &(s, e)) in self.per_core_sliced.iter().zip(&self.assign) {
            if s == e {
                continue;
            }
            slowest = slowest.max(out.batch_cycles.total());
            for (local, class) in (s..e).enumerate() {
                merged.class_sums[class * padded..(class + 1) * padded]
                    .copy_from_slice(&out.class_sums[local * padded..(local + 1) * padded]);
            }
        }
        merged.batch_cycles = slowest + self.classes as u64 + 1;
        argmax_rows(&merged.class_sums, padded, self.classes, &mut merged.preds);
        Ok(&self.sliced_merged)
    }

    /// Convenience mirror of [`Self::run_rows`] on the sliced kernel.
    pub fn run_rows_sliced(&mut self, rows: &[Vec<u8>]) -> Result<Vec<usize>, CoreError> {
        let n = rows.len();
        let r = self.run_rows_sliced_ref(rows)?;
        Ok(r.preds[..n].iter().map(|&p| p as usize).collect())
    }

    /// Bulk execution pinned to every core's compressed include-list
    /// kernel (bench/diagnostic twin of [`Self::run_rows_sliced_ref`]).
    pub fn run_rows_compressed_ref(
        &mut self,
        rows: &[Vec<u8>],
    ) -> Result<&MultiSlicedRun, CoreError> {
        self.run_rows_kernel_ref(rows, SlicedKernel::Compressed)
    }

    /// Convenience mirror of [`Self::run_rows`] on the compressed kernel.
    pub fn run_rows_compressed(&mut self, rows: &[Vec<u8>]) -> Result<Vec<usize>, CoreError> {
        let n = rows.len();
        let r = self.run_rows_compressed_ref(rows)?;
        Ok(r.preds[..n].iter().map(|&p| p as usize).collect())
    }

    /// The fan-out half of the sliced run: every non-idle core executes
    /// the (broadcast) transposed batch over its class range, threaded
    /// per [`Self::parallel`] — byte-identical results either way.
    fn run_sliced_cores(
        &mut self,
        batch: &SlicedBatch,
        batches: usize,
        kernel: SlicedKernel,
    ) -> Result<(), CoreError> {
        if self.per_core_sliced.len() != self.assign.len() {
            self.per_core_sliced
                .resize_with(self.assign.len(), SlicedResult::default);
        }
        if self.use_threads(batches) {
            let mut slots: Vec<Option<CoreError>> = Vec::new();
            slots.resize_with(self.assign.len(), || None);
            std::thread::scope(|scope| {
                for (((core, &(s, e)), out), slot) in self
                    .cores
                    .iter_mut()
                    .zip(&self.assign)
                    .zip(self.per_core_sliced.iter_mut())
                    .zip(slots.iter_mut())
                {
                    if s == e {
                        continue;
                    }
                    scope.spawn(move || {
                        *slot = core.run_kernel_into(batch, out, kernel).err();
                    });
                }
            });
            if let Some(e) = slots.into_iter().flatten().next() {
                return Err(e);
            }
        } else {
            for ((core, &(s, e)), out) in self
                .cores
                .iter_mut()
                .zip(&self.assign)
                .zip(self.per_core_sliced.iter_mut())
            {
                if s == e {
                    continue;
                }
                core.run_kernel_into(batch, out, kernel)?;
            }
        }
        Ok(())
    }

    /// Merge per-core batch results: gather class sums into global
    /// order, take the slowest core + merge cycles, global argmax.
    fn merge_batch(&self, per_core: Vec<Option<BatchResult>>) -> MultiBatchResult {
        let mut sums = vec![[0i32; 32]; self.classes];
        let mut slowest: u64 = 0;
        for (r, &(s, e)) in per_core.iter().zip(&self.assign) {
            if let Some(r) = r {
                slowest = slowest.max(r.cycles.total());
                for (local, class) in (s..e).enumerate() {
                    sums[class] = r.class_sums[local];
                }
            }
        }
        let merge_cycles = self.classes as u64 + 1;
        let preds = argmax_lanes(&sums);
        MultiBatchResult { class_sums: sums, preds, batch_cycles: slowest + merge_cycles, per_core }
    }

    /// Convenience mirror of `Core::run_rows`.
    pub fn run_rows(&mut self, rows: &[Vec<u8>]) -> Result<Vec<usize>, CoreError> {
        let n = rows.len();
        let packed = isa::pack_features(rows);
        let r = self.run_batch(&packed)?;
        Ok(r.preds[..n].iter().map(|&p| p as usize).collect())
    }

    /// Seconds for `cycles` at the multi-core clock.
    pub fn seconds(&self, cycles: u64) -> f64 {
        cycles as f64 / (self.cores[0].cfg.freq_mhz * 1e6)
    }
}

/// Batch result with parallel timing.
#[derive(Debug, Clone)]
pub struct MultiBatchResult {
    pub class_sums: Vec<[i32; 32]>,
    pub preds: [u8; 32],
    /// max(core cycles) + merge.
    pub batch_cycles: u64,
    pub per_core: Vec<Option<BatchResult>>,
}

impl MultiBatchResult {
    /// Cycle total had the cores run sequentially (single-core
    /// equivalent work) — used to report parallel speedup.
    pub fn sequential_cycles(&self) -> u64 {
        self.per_core
            .iter()
            .flatten()
            .map(|r| r.cycles.total())
            .sum()
    }
}

#[allow(unused_imports)]
use super::core::PipelineMode;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::synth::SynthSpec;
    use crate::tm::reference;
    use crate::TMShape;

    fn trained(classes: usize) -> (TMModel, crate::datasets::synth::Dataset) {
        let shape = TMShape::synthetic(12, classes, 8);
        let data = SynthSpec::new(12, classes, 256).noise(0.05).seed(13).generate();
        let model = crate::trainer::train_model(&shape, &data, 4, 6);
        (model, data)
    }

    #[test]
    fn partition_covers_all_classes_contiguously() {
        let weights = vec![10, 30, 5, 5, 40, 10, 20, 8];
        for n in 1..=8 {
            let p = MultiCore::partition(&weights, n);
            assert_eq!(p.len(), n.min(8));
            assert_eq!(p[0].0, 0);
            assert_eq!(p.last().unwrap().1, 8);
            for w in p.windows(2) {
                assert_eq!(w[0].1, w[1].0, "contiguous");
            }
        }
    }

    #[test]
    fn partition_balances_weighted_classes() {
        // One heavy class should sit alone.
        let weights = vec![100, 1, 1, 1, 1];
        let p = MultiCore::partition(&weights, 2);
        assert_eq!(p[0], (0, 1));
        assert_eq!(p[1], (1, 5));
    }

    #[test]
    fn multicore_matches_single_core_predictions() {
        let (model, data) = trained(6);
        let mut single = Core::new(AccelConfig::single_core());
        single.program_model(&model).unwrap();
        let mut multi = MultiCore::five_core();
        multi.program_model(&model).unwrap();

        let rows: Vec<Vec<u8>> = data.xs[..32].to_vec();
        let packed = isa::pack_features(&rows);
        let rs = single.run_batch(&packed).unwrap();
        let rm = multi.run_batch(&packed).unwrap();
        assert_eq!(rs.preds, rm.preds);
        for m in 0..6 {
            assert_eq!(rs.class_sums[m], rm.class_sums[m], "class {m}");
        }
    }

    #[test]
    fn multicore_is_faster_than_sequential() {
        let (model, data) = trained(6);
        let mut multi = MultiCore::five_core();
        multi.program_model(&model).unwrap();
        let packed = isa::pack_features(&data.xs[..32].to_vec());
        let r = multi.run_batch(&packed).unwrap();
        assert!(
            r.batch_cycles < r.sequential_cycles(),
            "parallel {} !< sequential {}",
            r.batch_cycles,
            r.sequential_cycles()
        );
    }

    #[test]
    fn more_cores_than_classes_leaves_idle_cores() {
        let (model, data) = trained(3);
        let mut multi = MultiCore::new(5, AccelConfig::multicore_core());
        multi.program_model(&model).unwrap();
        let idle = multi.assign.iter().filter(|&&(s, e)| s == e).count()
            + (5 - multi.assign.len());
        assert!(multi.assign.len() <= 5);
        let rows: Vec<Vec<u8>> = data.xs[..8].to_vec();
        let preds = multi.run_rows(&rows).unwrap();
        for (x, &p) in rows.iter().zip(&preds) {
            let lits = reference::literals_from_features(x);
            assert_eq!(p, reference::predict_dense(&model, &lits));
        }
        let _ = idle;
    }

    #[test]
    fn unprogrammed_multicore_errors() {
        let mut multi = MultiCore::five_core();
        assert!(matches!(multi.run_batch(&[0u32; 4]), Err(CoreError::NotProgrammed)));
        let batch = [0u32; 4];
        assert!(matches!(
            multi.run_batches(&[&batch]),
            Err(CoreError::NotProgrammed)
        ));
    }

    fn assert_multi_eq(a: &MultiBatchResult, b: &MultiBatchResult) {
        assert_eq!(a.class_sums, b.class_sums);
        assert_eq!(a.preds, b.preds);
        assert_eq!(a.batch_cycles, b.batch_cycles);
        assert_eq!(a.per_core, b.per_core);
    }

    #[test]
    fn serial_and_threaded_agree_exactly() {
        let (model, data) = trained(6);
        let packed = isa::pack_features(&data.xs[..32].to_vec());
        let mut serial = MultiCore::five_core().with_parallel(ParallelMode::Serial);
        serial.program_model(&model).unwrap();
        let mut threaded = MultiCore::five_core().with_parallel(ParallelMode::Threads);
        threaded.program_model(&model).unwrap();
        let rs = serial.run_batch(&packed).unwrap();
        let rt = threaded.run_batch(&packed).unwrap();
        assert_multi_eq(&rs, &rt);
        // Per-core lifetime stats agree too.
        for (a, b) in serial.cores.iter().zip(&threaded.cores) {
            assert_eq!(a.stats, b.stats);
        }
    }

    #[test]
    fn run_batches_matches_repeated_run_batch() {
        let (model, data) = trained(6);
        let a = isa::pack_features(&data.xs[..32].to_vec());
        let b = isa::pack_features(&data.xs[32..64].to_vec());

        let mut one = MultiCore::five_core().with_parallel(ParallelMode::Serial);
        one.program_model(&model).unwrap();
        let r1 = one.run_batch(&a).unwrap();
        let r2 = one.run_batch(&b).unwrap();

        let mut many = MultiCore::five_core().with_parallel(ParallelMode::Threads);
        many.program_model(&model).unwrap();
        let rs = many.run_batches(&[&a[..], &b[..]]).unwrap();
        assert_eq!(rs.len(), 2);
        assert_multi_eq(&rs[0], &r1);
        assert_multi_eq(&rs[1], &r2);
    }

    #[test]
    fn sliced_multicore_matches_batch_walk_and_is_schedule_invariant() {
        let (model, data) = trained(6);
        let rows: Vec<Vec<u8>> = (0..100).map(|i| data.xs[i % data.len()].clone()).collect();

        // 32-lane reference: per-batch multicore walk.
        let mut reference = MultiCore::five_core().with_parallel(ParallelMode::Serial);
        reference.program_model(&model).unwrap();
        let per_batch: Vec<MultiBatchResult> = rows
            .chunks(32)
            .map(|c| reference.run_batch(&isa::pack_features(c)).unwrap())
            .collect();

        for mode in [ParallelMode::Serial, ParallelMode::Threads] {
            let mut mc = MultiCore::five_core().with_parallel(mode);
            mc.program_model(&model).unwrap();
            // Clone out of the scratch so `mc.cores` is free for the
            // lifetime-stats asserts below.
            let r = mc.run_rows_sliced_ref(&rows).unwrap().clone();
            assert_eq!(r.rows, 100);
            assert_eq!(r.batches, 4);
            for row in 0..rows.len() {
                let b = &per_batch[row / 32];
                let lane = row % 32;
                assert_eq!(r.preds[row], b.preds[lane], "{mode:?} row {row}");
                for class in 0..6 {
                    assert_eq!(
                        r.class_sum(class, row),
                        b.class_sums[class][lane],
                        "{mode:?} row {row} class {class}"
                    );
                }
            }
            assert_eq!(r.batch_cycles, per_batch[0].batch_cycles, "{mode:?}");
            // Per-core lifetime stats advance exactly like the
            // 32-lane walk over the same batches.
            for (a, b) in mc.cores.iter().zip(&reference.cores) {
                assert_eq!(a.stats, b.stats, "{mode:?}");
            }
        }
    }

    #[test]
    fn sliced_multicore_handles_idle_cores_and_errors() {
        // More cores than classes: idle cores skipped, preds match the
        // dense reference.
        let (model, data) = trained(3);
        let mut mc =
            MultiCore::new(5, AccelConfig::multicore_core()).with_parallel(ParallelMode::Threads);
        assert!(matches!(
            mc.run_rows_sliced(&data.xs[..4].to_vec()),
            Err(CoreError::NotProgrammed)
        ));
        mc.program_model(&model).unwrap();
        assert!(matches!(
            mc.run_rows_sliced(&[]),
            Err(CoreError::BadBatch { rows: 0, .. })
        ));
        let rows: Vec<Vec<u8>> = data.xs[..70].to_vec();
        let preds = mc.run_rows_sliced(&rows).unwrap();
        for (x, &p) in rows.iter().zip(&preds) {
            let lits = reference::literals_from_features(x);
            assert_eq!(p, reference::predict_dense(&model, &lits));
        }
    }

    #[test]
    fn auto_mode_thresholds_on_scheduled_work() {
        let (model, data) = trained(6);
        let mut auto = MultiCore::five_core(); // Auto is the default
        assert_eq!(auto.parallel, ParallelMode::Auto);
        auto.program_model(&model).unwrap();
        let heaviest = auto
            .cores
            .iter()
            .map(|c| c.instruction_count())
            .max()
            .unwrap();
        assert!(heaviest > 0);
        // A tiny model on a single batch stays serial; enough batches
        // to cross AUTO_THREAD_MIN_OPS instruction slots threads.
        assert!(!auto.use_threads(1));
        assert!(auto.use_threads(AUTO_THREAD_MIN_OPS / heaviest + 1));

        // Whatever Auto decides, results equal the pinned-serial path.
        let packed = isa::pack_features(&data.xs[..32].to_vec());
        let ra = auto.run_batch(&packed).unwrap();
        let mut serial = MultiCore::five_core().with_parallel(ParallelMode::Serial);
        serial.program_model(&model).unwrap();
        let rs = serial.run_batch(&packed).unwrap();
        assert_multi_eq(&ra, &rs);
    }

    #[test]
    fn threaded_handles_idle_cores() {
        // More cores than classes: idle cores must be skipped, not
        // spawned, and results still match the dense reference.
        let (model, data) = trained(3);
        let mut multi =
            MultiCore::new(5, AccelConfig::multicore_core()).with_parallel(ParallelMode::Threads);
        multi.program_model(&model).unwrap();
        let rows: Vec<Vec<u8>> = data.xs[..8].to_vec();
        let preds = multi.run_rows(&rows).unwrap();
        for (x, &p) in rows.iter().zip(&preds) {
            let lits = reference::literals_from_features(x);
            assert_eq!(p, reference::predict_dense(&model, &lits));
        }
    }
}
