//! The AXIS-connected multi-core build (Fig 7).
//!
//! Each inference core is a base core; the AXIS splitter writes each
//! core's instruction memory with the instructions of a *non-overlapping
//! class range* but broadcasts the same features to every feature
//! memory.  Class-level parallelism: batch latency = slowest core +
//! merge.  The partitioner balances *instruction counts* (include
//! counts), not class counts — include-heavy classes dominate a core's
//! walk time.

use super::core::{argmax_lanes, AccelConfig, BatchResult, Core, CoreError};
use crate::isa;
use crate::tm::model::TMModel;

/// A multi-core accelerator with class partitioning.
pub struct MultiCore {
    pub cores: Vec<Core>,
    /// Class ranges (contiguous) per core; `assign[i]` = (start, end).
    pub assign: Vec<(usize, usize)>,
    pub classes: usize,
}

impl MultiCore {
    /// The paper's 5-core M configuration (Table 1/Table 2).
    pub fn five_core() -> Self {
        Self::new(5, AccelConfig::multicore_core())
    }

    pub fn new(n: usize, per_core: AccelConfig) -> Self {
        assert!(n >= 1);
        MultiCore {
            cores: (0..n).map(|_| Core::new(per_core.clone())).collect(),
            assign: Vec::new(),
            classes: 0,
        }
    }

    pub fn n_cores(&self) -> usize {
        self.cores.len()
    }

    /// Balanced contiguous partition of classes by per-class instruction
    /// count (greedy block fill against the ideal share).
    pub fn partition(per_class_instrs: &[usize], n_cores: usize) -> Vec<(usize, usize)> {
        let classes = per_class_instrs.len();
        let n = n_cores.min(classes).max(1);
        let total: usize = per_class_instrs.iter().sum();
        let mut bounds = Vec::with_capacity(n);
        let mut start = 0usize;
        let mut cum = 0usize;
        for (c, &w) in per_class_instrs.iter().enumerate() {
            cum += w;
            let remaining_classes = classes - c - 1;
            let remaining_cores = n - bounds.len() - 1;
            // Close the current block once the cumulative weight crosses
            // this block's ideal boundary, but never leave fewer classes
            // than cores still to fill.
            let boundary = (total as f64) * (bounds.len() + 1) as f64 / n as f64;
            if bounds.len() < n - 1
                && (cum as f64 + 1e-9 >= boundary || remaining_classes == remaining_cores)
            {
                bounds.push((start, c + 1));
                start = c + 1;
            }
        }
        bounds.push((start, classes));
        debug_assert_eq!(bounds.len(), n);
        bounds
    }

    /// Program all cores from a dense model (the AXIS split of the
    /// instruction stream).
    pub fn program_model(&mut self, model: &TMModel) -> Result<(), CoreError> {
        let per_class = model
            .includes_per_class()
            .iter()
            .map(|&n| if n == 0 { 2 } else { n })
            .collect::<Vec<_>>();
        let assign = Self::partition(&per_class, self.cores.len());
        self.classes = model.shape.classes;
        for (core, &(s, e)) in self.cores.iter_mut().zip(&assign) {
            if s == e {
                // More cores than classes: leave idle.
                continue;
            }
            let slice = model.slice_classes(s..e);
            core.program_model(&slice)?;
        }
        self.assign = assign;
        Ok(())
    }

    /// Run one bit-sliced batch on all cores (features broadcast),
    /// merging class sums and taking the global argmax.
    ///
    /// Timing: cores run in parallel -> batch cycles = max over cores;
    /// the merge adds one cycle per class (sum gather) plus the argmax
    /// chain, modeled in `merge_cycles`.
    pub fn run_batch(&mut self, packed_features: &[u32]) -> Result<MultiBatchResult, CoreError> {
        if self.assign.is_empty() {
            return Err(CoreError::NotProgrammed);
        }
        let mut sums = vec![[0i32; 32]; self.classes];
        let mut slowest: u64 = 0;
        let mut per_core = Vec::with_capacity(self.cores.len());
        for (core, &(s, e)) in self.cores.iter_mut().zip(&self.assign) {
            if s == e {
                per_core.push(None);
                continue;
            }
            let r = core.run_batch(packed_features)?;
            slowest = slowest.max(r.cycles.total());
            for (local, class) in (s..e).enumerate() {
                sums[class] = r.class_sums[local];
            }
            per_core.push(Some(r));
        }
        let merge_cycles = self.classes as u64 + 1;
        let preds = argmax_lanes(&sums);
        Ok(MultiBatchResult { class_sums: sums, preds, batch_cycles: slowest + merge_cycles, per_core })
    }

    /// Convenience mirror of `Core::run_rows`.
    pub fn run_rows(&mut self, rows: &[Vec<u8>]) -> Result<Vec<usize>, CoreError> {
        let n = rows.len();
        let packed = isa::pack_features(rows);
        let r = self.run_batch(&packed)?;
        Ok(r.preds[..n].iter().map(|&p| p as usize).collect())
    }

    /// Seconds for `cycles` at the multi-core clock.
    pub fn seconds(&self, cycles: u64) -> f64 {
        cycles as f64 / (self.cores[0].cfg.freq_mhz * 1e6)
    }
}

/// Batch result with parallel timing.
#[derive(Debug, Clone)]
pub struct MultiBatchResult {
    pub class_sums: Vec<[i32; 32]>,
    pub preds: [u8; 32],
    /// max(core cycles) + merge.
    pub batch_cycles: u64,
    pub per_core: Vec<Option<BatchResult>>,
}

impl MultiBatchResult {
    /// Cycle total had the cores run sequentially (single-core
    /// equivalent work) — used to report parallel speedup.
    pub fn sequential_cycles(&self) -> u64 {
        self.per_core
            .iter()
            .flatten()
            .map(|r| r.cycles.total())
            .sum()
    }
}

#[allow(unused_imports)]
use super::core::PipelineMode;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::synth::SynthSpec;
    use crate::tm::reference;
    use crate::TMShape;

    fn trained(classes: usize) -> (TMModel, crate::datasets::synth::Dataset) {
        let shape = TMShape::synthetic(12, classes, 8);
        let data = SynthSpec::new(12, classes, 256).noise(0.05).seed(13).generate();
        let model = crate::trainer::train_model(&shape, &data, 4, 6);
        (model, data)
    }

    #[test]
    fn partition_covers_all_classes_contiguously() {
        let weights = vec![10, 30, 5, 5, 40, 10, 20, 8];
        for n in 1..=8 {
            let p = MultiCore::partition(&weights, n);
            assert_eq!(p.len(), n.min(8));
            assert_eq!(p[0].0, 0);
            assert_eq!(p.last().unwrap().1, 8);
            for w in p.windows(2) {
                assert_eq!(w[0].1, w[1].0, "contiguous");
            }
        }
    }

    #[test]
    fn partition_balances_weighted_classes() {
        // One heavy class should sit alone.
        let weights = vec![100, 1, 1, 1, 1];
        let p = MultiCore::partition(&weights, 2);
        assert_eq!(p[0], (0, 1));
        assert_eq!(p[1], (1, 5));
    }

    #[test]
    fn multicore_matches_single_core_predictions() {
        let (model, data) = trained(6);
        let mut single = Core::new(AccelConfig::single_core());
        single.program_model(&model).unwrap();
        let mut multi = MultiCore::five_core();
        multi.program_model(&model).unwrap();

        let rows: Vec<Vec<u8>> = data.xs[..32].to_vec();
        let packed = isa::pack_features(&rows);
        let rs = single.run_batch(&packed).unwrap();
        let rm = multi.run_batch(&packed).unwrap();
        assert_eq!(rs.preds, rm.preds);
        for m in 0..6 {
            assert_eq!(rs.class_sums[m], rm.class_sums[m], "class {m}");
        }
    }

    #[test]
    fn multicore_is_faster_than_sequential() {
        let (model, data) = trained(6);
        let mut multi = MultiCore::five_core();
        multi.program_model(&model).unwrap();
        let packed = isa::pack_features(&data.xs[..32].to_vec());
        let r = multi.run_batch(&packed).unwrap();
        assert!(
            r.batch_cycles < r.sequential_cycles(),
            "parallel {} !< sequential {}",
            r.batch_cycles,
            r.sequential_cycles()
        );
    }

    #[test]
    fn more_cores_than_classes_leaves_idle_cores() {
        let (model, data) = trained(3);
        let mut multi = MultiCore::new(5, AccelConfig::multicore_core());
        multi.program_model(&model).unwrap();
        let idle = multi.assign.iter().filter(|&&(s, e)| s == e).count()
            + (5 - multi.assign.len());
        assert!(multi.assign.len() <= 5);
        let rows: Vec<Vec<u8>> = data.xs[..8].to_vec();
        let preds = multi.run_rows(&rows).unwrap();
        for (x, &p) in rows.iter().zip(&preds) {
            let lits = reference::literals_from_features(x);
            assert_eq!(p, reference::predict_dense(&model, &lits));
        }
        let _ = idle;
    }

    #[test]
    fn unprogrammed_multicore_errors() {
        let mut multi = MultiCore::five_core();
        assert!(matches!(multi.run_batch(&[0u32; 4]), Err(CoreError::NotProgrammed)));
    }
}
