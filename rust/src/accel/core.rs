//! The base inference core (Fig 4): instruction fetch/decode, literal
//! select, clause update, class-sum accumulate, argmax, output FIFO —
//! with the Fig 5 cycle model.
//!
//! # Timing model
//!
//! Each instruction passes four stages (Fig 5.2): FETCH -> DECODE ->
//! LIT-SELECT -> CLAUSE-UPDATE, "a minimum of four clock cycles".
//! Two deploy-time variants:
//!
//! * [`PipelineMode::Pipelined`] (the paper's Fig 5 design): stages
//!   overlap, steady state retires one instruction per cycle; a clause
//!   boundary inserts one bubble (the class-sum accumulate reuses the
//!   adder port).  Execute cycles = 3 + N + clauses.
//! * [`PipelineMode::Iterative`]: the minimal-LUT variant with no
//!   overlap: 4 cycles per instruction + 1 per clause commit.
//!
//! After the walk: one accumulate-flush cycle per class, `classes`
//! comparison cycles for the sequential argmax, and FIFO fill cycles
//! (one per output word on the 32-bit output port: 8 for a 32-wide
//! batch of 8-bit classifications, 1 in single mode).
//!
//! Programming and feature loads move one stream word per cycle
//! (headers included) — the real design's AXIS port does exactly this.

use super::fifo::OutputFifo;
use super::memory::{FeatureMemory, InstrMemory, MemError};
use super::stream::{decode_stream, HeaderWidth, Message, StreamCodec, StreamError};
use crate::isa::{self, CompressedProgram, Instr, SlicedBatch, SlicedProgram, SoaProgram};

/// Which 64-lane bulk kernel a run uses.  Both concrete kernels are
/// byte-identical in every observable (preds, sums, simulated cycles,
/// FIFO, lifetime counters); the choice only moves host wall-clock, so
/// `Auto` is always safe and resolves to the density-based decision
/// made once at program time (sparse include lists -> `Compressed`,
/// dense -> `Sliced`).  Pinned variants exist for benches and
/// equivalence tests.
#[derive(Debug, Copy, Clone, PartialEq, Eq, Default)]
pub enum SlicedKernel {
    #[default]
    Auto,
    /// The dense 64-lane plane walk ([`SlicedProgram`]).
    Sliced,
    /// The sparse include-list gather ([`CompressedProgram`]).
    Compressed,
}

/// Deploy-time configuration of one core (the Fig 8 "one-time
/// implementation" choices).
#[derive(Debug, Clone)]
pub struct AccelConfig {
    pub name: &'static str,
    pub header_width: HeaderWidth,
    pub instr_depth: usize,
    pub feature_depth: usize,
    pub fifo_depth: usize,
    pub freq_mhz: f64,
    pub pipeline: PipelineMode,
}

#[derive(Debug, Copy, Clone, PartialEq, Eq)]
pub enum PipelineMode {
    Pipelined,
    Iterative,
}

impl AccelConfig {
    /// Base standalone build (Table 1: Artix A7035, 200 MHz).
    pub fn base() -> Self {
        AccelConfig {
            name: "base",
            header_width: HeaderWidth::W32,
            instr_depth: 8192,
            feature_depth: 2048,
            fifo_depth: 64,
            freq_mhz: 200.0,
            pipeline: PipelineMode::Pipelined,
        }
    }

    /// AXIS single core (Table 1: Zynq Z7020, 100 MHz, deeper memories —
    /// "BRAMs ... over-provisioned for more tunability later").
    pub fn single_core() -> Self {
        AccelConfig {
            name: "single_core",
            header_width: HeaderWidth::W32,
            instr_depth: 28672,
            feature_depth: 8192,
            fifo_depth: 128,
            freq_mhz: 100.0,
            pipeline: PipelineMode::Pipelined,
        }
    }

    /// Per-core config inside the multi-core build (Fig 7).
    pub fn multicore_core() -> Self {
        AccelConfig {
            name: "multicore",
            header_width: HeaderWidth::W32,
            instr_depth: 4096,
            feature_depth: 2048,
            fifo_depth: 128,
            freq_mhz: 100.0,
            pipeline: PipelineMode::Pipelined,
        }
    }

    pub fn with_depths(mut self, instr: usize, feature: usize) -> Self {
        self.instr_depth = instr;
        self.feature_depth = feature;
        self
    }

    pub fn with_pipeline(mut self, p: PipelineMode) -> Self {
        self.pipeline = p;
        self
    }

    /// Total BRAM18 blocks of this configuration.
    pub fn brams(&self) -> usize {
        InstrMemory::new(self.instr_depth).brams()
            + FeatureMemory::new(self.feature_depth).brams()
            + 1 // output FIFO + stream buffer
    }
}

/// Cumulative cycle accounting, by phase (Fig 5.1).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct CycleStats {
    pub program: u64,
    pub feature_load: u64,
    pub execute: u64,
    pub commit: u64,
    pub argmax: u64,
    pub fifo: u64,
}

impl CycleStats {
    pub fn total(&self) -> u64 {
        self.program + self.feature_load + self.execute + self.commit + self.argmax + self.fifo
    }

    /// Inference-only cycles (excludes one-time programming).
    pub fn inference(&self) -> u64 {
        self.total() - self.program
    }
}

/// One 32-datapoint batch result.
///
/// Reusable: [`Core::run_batch_into`] overwrites an existing result in
/// place (no allocation once `class_sums` has capacity), which is how
/// the zero-alloc serving loop runs — see EXPERIMENTS.md §Perf.
#[derive(Debug, Clone, PartialEq)]
pub struct BatchResult {
    /// Per-class bit-sliced sums.
    pub class_sums: Vec<[i32; 32]>,
    /// argmax per datapoint lane.
    pub preds: [u8; 32],
    /// Cycles spent on THIS batch (feature load + execute + ... ).
    pub cycles: CycleStats,
}

impl Default for BatchResult {
    fn default() -> Self {
        BatchResult { class_sums: Vec::new(), preds: [0u8; 32], cycles: CycleStats::default() }
    }
}

/// Result (and reusable buffers) of one bit-sliced bulk run — any row
/// count, 64 rows per bitwise op (§Bit-sliced in EXPERIMENTS.md).
///
/// Observable values are byte-identical to running the same rows
/// through [`Core::run_batch_into`] in 32-row chunks: the per-row
/// `class_sums`, the per-row argmax `preds` (padding rows argmax the
/// all-zero-feature row, exactly like the unused lanes of a ragged
/// batch), and the simulated cycle model — the sliced kernel is a HOST
/// fast path, never a different accelerator.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct SlicedResult {
    /// Class-major per-row sums: `class_sums[class * padded_rows + row]`.
    pub class_sums: Vec<i32>,
    /// Row count including the padding lanes of the last 64-row slice.
    pub padded_rows: usize,
    /// Real rows of the run.
    pub rows: usize,
    /// argmax per padded row (first-max tie-break, like `argmax_lanes`).
    pub preds: Vec<u8>,
    /// Simulated cycles of ONE equivalent 32-row batch.  Every batch of
    /// a run costs the same (the packed word count is the feature
    /// count, full or ragged), so per-batch cycles times `batches` is
    /// the run's total.
    pub batch_cycles: CycleStats,
    /// 32-row batches the equivalent SoA walk would run
    /// (`rows.div_ceil(32)`).
    pub batches: u64,
}

impl SlicedResult {
    /// One row's sum for one class.
    #[inline]
    pub fn class_sum(&self, class: usize, row: usize) -> i32 {
        self.class_sums[class * self.padded_rows + row]
    }

    /// Classes of the programmed model this run evaluated.
    pub fn classes(&self) -> usize {
        if self.padded_rows == 0 {
            0
        } else {
            self.class_sums.len() / self.padded_rows
        }
    }

    /// Total simulated cycles of the run (all batches).
    pub fn total_cycles(&self) -> u64 {
        self.batch_cycles.total() * self.batches
    }
}

/// Errors surfaced by the core's stream front-end.
#[derive(Debug, thiserror::Error, PartialEq)]
pub enum CoreError {
    #[error(transparent)]
    Stream(#[from] StreamError),
    #[error(transparent)]
    Mem(#[from] MemError),
    #[error(transparent)]
    Isa(#[from] isa::IsaError),
    #[error("no model programmed")]
    NotProgrammed,
    #[error("feature count {got} exceeds programmed expectation or memory")]
    BadFeatureCount { got: usize },
    #[error("malformed batch ({rows} rows): {reason}")]
    BadBatch { rows: usize, reason: &'static str },
}

/// One pipeline trace event (for the Fig 5 diagram bench).
#[derive(Debug, Clone, PartialEq)]
pub struct TraceEvent {
    pub cycle: u64,
    pub stage: &'static str,
    pub instr: usize,
}

/// The base inference core.
///
/// The walk state machine is resolved ONCE at program time into a
/// structure-of-arrays [`SoaProgram`] (the RTL's DECODE stage output):
/// flat feature addresses, per-op XOR masks folding the L bit, and a
/// commit table of contiguous clause segments.  Programming happens once
/// per model; batches run many times — the per-batch hot loop is a
/// branch-free AND-reduction with no allocation (§Perf in
/// EXPERIMENTS.md).
pub struct Core {
    pub cfg: AccelConfig,
    pub codec: StreamCodec,
    imem: InstrMemory,
    fmem: FeatureMemory,
    pub fifo: OutputFifo,
    /// Architecture parameters from the last Instruction Header.
    pub classes: usize,
    pub clauses: usize,
    /// Predecoded SoA program (rebuilt in place on every reprogram).
    prog: SoaProgram,
    /// The 64-lane derivation of `prog` (rebuilt alongside it).
    sliced: SlicedProgram,
    /// The compressed include-list derivation of `prog` (rebuilt
    /// alongside it, pruning off — always equivalence-safe).
    compressed: CompressedProgram,
    /// Program-time kernel decision for [`SlicedKernel::Auto`] runs:
    /// true when the compressed derivation measured sparse enough to
    /// beat the dense plane walk
    /// ([`super::engine::COMPRESSED_MAX_DENSITY`]).
    use_compressed: bool,
    /// Reusable result scratch for the convenience entry points
    /// (`run_rows`): keeps steady-state serving allocation-free.
    scratch: BatchResult,
    /// Reusable transpose scratch for `run_rows_sliced` (the pack-once
    /// half of the sliced path).
    sliced_batch: SlicedBatch,
    /// Reusable clause accumulator of the sliced walk (one `u64` per
    /// 64-row slice).
    sliced_cur: Vec<u64>,
    /// Reusable result scratch for the sliced convenience entry points.
    sliced_scratch: SlicedResult,
    /// Lifetime cycle counters.
    pub stats: CycleStats,
    /// Batches inferred since power-up.
    pub batches_run: u64,
    /// When true, `run_batch` records a pipeline trace (first 64 instrs).
    pub trace_enabled: bool,
    pub trace: Vec<TraceEvent>,
}

impl Core {
    pub fn new(cfg: AccelConfig) -> Self {
        Core {
            codec: StreamCodec::new(cfg.header_width),
            imem: InstrMemory::new(cfg.instr_depth),
            fmem: FeatureMemory::new(cfg.feature_depth),
            fifo: OutputFifo::new(cfg.fifo_depth),
            cfg,
            classes: 0,
            clauses: 0,
            prog: SoaProgram::default(),
            sliced: SlicedProgram::default(),
            compressed: CompressedProgram::default(),
            use_compressed: false,
            scratch: BatchResult::default(),
            sliced_batch: SlicedBatch::default(),
            sliced_cur: Vec::new(),
            sliced_scratch: SlicedResult::default(),
            stats: CycleStats::default(),
            batches_run: 0,
            trace_enabled: false,
            trace: Vec::new(),
        }
    }

    /// Out-of-band reset line: drop the programmed model, in-flight
    /// state and FIFO contents (the NEW_STREAM semantics for anything
    /// the in-band countdown framing cannot abort).
    pub fn reset(&mut self) {
        self.imem = InstrMemory::new(self.cfg.instr_depth);
        self.fmem = FeatureMemory::new(self.cfg.feature_depth);
        self.fifo = OutputFifo::new(self.cfg.fifo_depth);
        self.classes = 0;
        self.clauses = 0;
        self.prog.clear();
        self.sliced.clear();
        self.compressed.clear();
        self.use_compressed = false;
        self.trace.clear();
    }

    /// True once a model is loaded.
    pub fn is_programmed(&self) -> bool {
        !self.imem.is_empty() && self.classes > 0
    }

    pub fn instruction_count(&self) -> usize {
        self.imem.len()
    }

    /// Program a new model directly (bypassing stream framing); counts
    /// the stream cycles the words would have taken.
    ///
    /// Predecodes the walk (DECODE-stage work) once here, so per-batch
    /// execution is a tight loop over resolved micro-ops.
    pub fn program(&mut self, classes: usize, clauses: usize, instrs: &[Instr]) -> Result<(), CoreError> {
        self.imem.program(instrs)?;
        self.classes = classes;
        self.clauses = clauses;

        // Predecode into the SoA program (in place — reprogramming does
        // not allocate once buffers have grown).  TA bounds are
        // validated against the architectural maximum (the ISA's 12-bit
        // offset space); the per-batch check against the actual feature
        // count is O(1) via the cached `max_feat`.
        if let Err(e) = isa::predecode_into(instrs, classes, isa::MAX_LITERALS, &mut self.prog) {
            // A corrupt stream must not leave a half-predecoded walk
            // behind: un-program the core (instruction memory included,
            // so `instruction_count` never reports a rejected stream)
            // and let run_batch report NotProgrammed.
            self.imem = InstrMemory::new(self.cfg.instr_depth);
            self.classes = 0;
            self.clauses = 0;
            self.prog.clear();
            self.sliced.clear();
            self.compressed.clear();
            self.use_compressed = false;
            return Err(e.into());
        }
        // Derive the 64-lane twin (buffers reused; exclude-only and
        // tautology-killer clauses resolved here so the sliced inner
        // loop stays branch-free).
        isa::derive_sliced_into(&self.prog, classes, &mut self.sliced);
        // ... and its compressed include-list twin, deciding the Auto
        // bulk kernel ONCE from the density measured at derivation.
        // Both kernels are byte-identical, so this moves only host
        // wall-clock, never a simulated cycle.
        isa::derive_compressed_into(&self.prog, classes, &mut self.compressed);
        self.use_compressed =
            self.compressed.density <= super::engine::COMPRESSED_MAX_DENSITY;
        // 2 header words + payload, one word per cycle — counted only
        // for accepted streams so lifetime stats match a core that
        // never saw a rejected one.
        self.stats.program += 2 + self.codec.instruction_payload_len(instrs.len()) as u64;
        Ok(())
    }

    /// Program from a dense model (encodes through the ISA).
    pub fn program_model(&mut self, model: &crate::tm::model::TMModel) -> Result<(), CoreError> {
        let instrs = isa::encode(model);
        self.program(model.shape.classes, model.shape.clauses, &instrs)
    }

    /// FNV-1a digest over EVERY derived program buffer this core could
    /// execute from (SoA walk, sliced planes, compressed include lists,
    /// plus the kernel-selection bit) — the scrub layer's fence-time
    /// record and re-verify primitive.  `None` until programmed.
    pub fn program_digest(&self) -> Option<u64> {
        if !self.is_programmed() {
            return None;
        }
        let mut d = isa::ProgramDigest::new();
        d.u64(isa::digest_soa(&self.prog));
        d.u64(isa::digest_sliced(&self.sliced));
        d.u64(isa::digest_compressed(&self.compressed));
        d.byte(self.use_compressed as u8);
        Some(d.finish())
    }

    /// Fault injection: flip `n_bits` seeded pseudo-random bits across
    /// this core's OWN derived-program buffers (never a shared model) —
    /// the software analog of an SEU in model BRAM.  Bits are spread
    /// over whichever derivations exist, so whichever kernel the auto
    /// path selected is corrupted with certainty (distinct-bit flips
    /// land in every non-empty derivation when `n_bits >= 3`).  Returns
    /// bits actually flipped (0 when unprogrammed).
    pub fn flip_program_bits(&mut self, seed: u64, n_bits: u32) -> u32 {
        if !self.is_programmed() || n_bits == 0 {
            return 0;
        }
        // Deterministic round-robin over the three derivations with
        // per-derivation sub-seeds: every derivation that executes
        // (use_compressed picks ONE bulk kernel, but run_batch may
        // still walk the SoA form) gets at least one flip when
        // n_bits >= 3.
        let each = n_bits.div_ceil(3);
        let a = isa::flip_soa_bits(&mut self.prog, seed, each);
        let b = isa::flip_sliced_bits(&mut self.sliced, seed.wrapping_add(1), each);
        let c = isa::flip_compressed_bits(
            &mut self.compressed,
            seed.wrapping_add(2),
            n_bits.saturating_sub(2 * each).max(1),
        );
        a + b + c
    }

    /// Feed raw stream words (the real programming interface).  Returns
    /// batch results for any inference payloads in the stream.
    pub fn feed_stream(&mut self, words: &[u64]) -> Result<Vec<BatchResult>, CoreError> {
        let mut results = Vec::new();
        for msg in decode_stream(&self.codec, words)? {
            match msg {
                Message::Program { classes, clauses, instrs } => {
                    self.program(classes, clauses, &instrs)?;
                }
                Message::Infer { features: _, batches } => {
                    for b in &batches {
                        results.push(self.run_batch(b)?);
                    }
                }
            }
        }
        Ok(results)
    }

    /// Load one bit-sliced batch into feature memory and execute the
    /// programmed instruction walk over it.
    pub fn run_batch(&mut self, packed_features: &[u32]) -> Result<BatchResult, CoreError> {
        let mut out = BatchResult::default();
        self.run_batch_into(packed_features, &mut out)?;
        Ok(out)
    }

    /// Zero-alloc batch execution: overwrite `out` in place.  Once
    /// `out.class_sums` has capacity for `classes` rows (after the first
    /// call), the steady-state loop performs no heap allocation — the
    /// feature memory, the SoA program and the result buffers are all
    /// reused (§Perf in EXPERIMENTS.md).
    pub fn run_batch_into(
        &mut self,
        packed_features: &[u32],
        out: &mut BatchResult,
    ) -> Result<(), CoreError> {
        if !self.is_programmed() {
            return Err(CoreError::NotProgrammed);
        }
        // O(1) bounds check for the whole walk (program() resolved and
        // cached every TA): the largest feature address must sit inside
        // this batch.  No per-batch rescan of the program.
        if let Some(max_feat) = self.prog.max_feat {
            if max_feat as usize >= packed_features.len() {
                return Err(CoreError::Isa(isa::IsaError::OffsetOverrun {
                    index: 0,
                    ta: 2 * max_feat as usize,
                    literals: 2 * packed_features.len(),
                }));
            }
        }
        self.fmem.load(packed_features)?;

        out.cycles = CycleStats {
            // 2 header words + payload words, 1/cycle.
            feature_load: 2 + self.codec.feature_payload_len(packed_features.len()) as u64,
            ..CycleStats::default()
        };

        // Reset sums without reallocating.
        out.class_sums.clear();
        out.class_sums.resize(self.classes, [0i32; 32]);

        // Hot loop: branch-free AND-reduction over contiguous clause
        // segments of the SoA program (see SoaProgram docs /
        // EXPERIMENTS.md §Perf).
        let clause_count = self.prog.execute_into(self.fmem.words(), &mut out.class_sums);

        let n = self.imem.len();
        self.trace.clear();
        if self.trace_enabled {
            for i in 0..n.min(64) {
                self.record_trace(i, clause_count, out.cycles.feature_load);
            }
        }

        // Fig 5 timing.
        out.cycles.execute = match self.cfg.pipeline {
            PipelineMode::Pipelined => {
                if n == 0 {
                    0
                } else {
                    3 + n as u64
                }
            }
            PipelineMode::Iterative => 4 * n as u64,
        };
        out.cycles.commit = clause_count;
        out.cycles.argmax = self.classes as u64; // sequential compare chain
        out.preds = argmax_lanes(&out.class_sums);
        // FIFO fill: 8-bit classes over the 32-bit output port.
        out.cycles.fifo = (32 * 8 / 32) as u64;
        self.fifo.push_batch(&out.preds);

        self.accumulate(&out.cycles);
        self.batches_run += 1;
        Ok(())
    }

    /// Execute a stream of batches, amortizing per-call setup: one
    /// programmed-check, reused feature memory, results allocated once
    /// up front.  Semantically identical to calling [`Self::run_batch`]
    /// per element (byte-identical `BatchResult`s, same `CycleStats`
    /// accumulation).
    pub fn run_batches(&mut self, batches: &[&[u32]]) -> Result<Vec<BatchResult>, CoreError> {
        if !self.is_programmed() {
            return Err(CoreError::NotProgrammed);
        }
        let mut out = Vec::with_capacity(batches.len());
        for &packed in batches {
            let mut r = BatchResult::default();
            self.run_batch_into(packed, &mut r)?;
            out.push(r);
        }
        Ok(out)
    }

    /// Convenience: run <= 32 datapoints given as feature rows; returns
    /// per-datapoint predictions.  Uses the core's reusable scratch
    /// result (no per-call sums allocation).
    pub fn run_rows(&mut self, rows: &[Vec<u8>]) -> Result<Vec<usize>, CoreError> {
        let n = rows.len();
        let packed = isa::pack_features(rows);
        let mut scratch = std::mem::take(&mut self.scratch);
        let res = self.run_batch_into(&packed, &mut scratch);
        let preds = scratch.preds;
        self.scratch = scratch;
        res?;
        Ok(preds[..n].iter().map(|&p| p as usize).collect())
    }

    /// Execute the 64-lane bit-sliced kernel over a transposed batch,
    /// overwriting `out` in place (zero heap allocation once `out`'s
    /// buffers have capacity).  Observable behavior — per-row sums,
    /// preds, simulated cycles, FIFO contents, lifetime counters — is
    /// byte-identical to running the same rows through
    /// [`Self::run_batch_into`] in 32-row chunks; only host wall-clock
    /// changes (§Bit-sliced in EXPERIMENTS.md).  The sliced path does
    /// not record pipeline traces (use `run_batch` for the Fig 5
    /// diagram).
    pub fn run_sliced_into(
        &mut self,
        batch: &SlicedBatch,
        out: &mut SlicedResult,
    ) -> Result<(), CoreError> {
        self.run_kernel_into(batch, out, SlicedKernel::Sliced)
    }

    /// [`Self::run_sliced_into`] pinned to the sparse include-list
    /// kernel — same observables (the compressed derivation is pruning-
    /// free), different host loop.
    pub fn run_compressed_into(
        &mut self,
        batch: &SlicedBatch,
        out: &mut SlicedResult,
    ) -> Result<(), CoreError> {
        self.run_kernel_into(batch, out, SlicedKernel::Compressed)
    }

    /// The shared 64-lane bulk run: every check, the cycle model, FIFO
    /// and lifetime accounting are kernel-independent; `kernel` picks
    /// only which derived program walks the planes (`Auto` resolves to
    /// the program-time density decision).
    pub fn run_kernel_into(
        &mut self,
        batch: &SlicedBatch,
        out: &mut SlicedResult,
        kernel: SlicedKernel,
    ) -> Result<(), CoreError> {
        if !self.is_programmed() {
            return Err(CoreError::NotProgrammed);
        }
        if batch.rows == 0 {
            return Err(CoreError::BadBatch { rows: 0, reason: "empty request" });
        }
        // Bounds parity with `run_batch`: the UNDERIVED program's
        // largest feature address must sit inside this batch (the
        // derivation may have dropped the clause holding it).
        if let Some(max_feat) = self.prog.max_feat {
            if max_feat as usize >= batch.features {
                return Err(CoreError::Isa(isa::IsaError::OffsetOverrun {
                    index: 0,
                    ta: 2 * max_feat as usize,
                    literals: 2 * batch.features,
                }));
            }
        }
        // Capacity parity: a batch the Feature Memory cannot hold is
        // rejected with the same typed error either way.
        if batch.features > self.cfg.feature_depth {
            return Err(CoreError::Mem(MemError::FeatureOverflow {
                need: batch.features,
                depth: self.cfg.feature_depth,
            }));
        }

        let padded = batch.padded_rows();
        out.rows = batch.rows;
        out.padded_rows = padded;
        out.class_sums.clear();
        out.class_sums.resize(self.classes * padded, 0);
        match self.resolve_kernel(kernel) {
            SlicedKernel::Compressed => {
                self.compressed
                    .execute_into(batch, &mut out.class_sums, &mut self.sliced_cur)
            }
            _ => self
                .sliced
                .execute_into(batch, &mut out.class_sums, &mut self.sliced_cur),
        };

        argmax_rows(&out.class_sums, padded, self.classes, &mut out.preds);

        // Fig 5 timing of the EQUIVALENT 32-lane walk: every 32-row
        // batch of this run costs the same (the packed word count is
        // the feature count, full or ragged), and resolved clauses
        // still cost their commit cycle.
        let n = self.imem.len() as u64;
        out.batch_cycles = CycleStats {
            program: 0,
            feature_load: 2 + self.codec.feature_payload_len(batch.features) as u64,
            execute: match self.cfg.pipeline {
                PipelineMode::Pipelined => {
                    if n == 0 {
                        0
                    } else {
                        3 + n
                    }
                }
                PipelineMode::Iterative => 4 * n,
            },
            commit: self.prog.clause_count() as u64,
            argmax: self.classes as u64,
            fifo: (32 * 8 / 32) as u64,
        };
        out.batches = (batch.rows as u64).div_ceil(32);

        // Observable side effects of the equivalent per-batch walk:
        // the FIFO sees exactly ceil(rows/32) batches of 32 preds
        // (padding rows argmax the all-zero-feature row, matching the
        // unused lanes of a ragged batch), lifetime counters advance
        // by `batches` worth of cycles.  `padded >= batches * 32`
        // always: ceil(r/64)*64 >= ceil(r/32)*32.
        self.trace.clear();
        for chunk in out.preds[..out.batches as usize * 32].chunks(32) {
            self.fifo.push_batch(chunk);
        }
        self.accumulate_scaled(&out.batch_cycles, out.batches);
        self.batches_run += out.batches;
        Ok(())
    }

    /// Pack `rows` (any count >= 1) into the core-owned transpose
    /// scratch and run the sliced kernel into the core-owned result
    /// scratch; returns a borrow of that result.  The bulk scheduler's
    /// entry point — steady-state serving performs no heap allocation.
    pub fn run_rows_sliced_ref(&mut self, rows: &[Vec<u8>]) -> Result<&SlicedResult, CoreError> {
        self.run_rows_kernel_ref(rows, SlicedKernel::Sliced)
    }

    /// [`Self::run_rows_sliced_ref`] pinned to the sparse include-list
    /// kernel.
    pub fn run_rows_compressed_ref(&mut self, rows: &[Vec<u8>]) -> Result<&SlicedResult, CoreError> {
        self.run_rows_kernel_ref(rows, SlicedKernel::Compressed)
    }

    /// Pack `rows` into the core-owned scratch and run the chosen
    /// 64-lane kernel — the kernel-generic body behind the pinned
    /// `run_rows_{sliced,compressed}_ref` entry points and the engine's
    /// auto path.
    pub fn run_rows_kernel_ref(
        &mut self,
        rows: &[Vec<u8>],
        kernel: SlicedKernel,
    ) -> Result<&SlicedResult, CoreError> {
        if rows.is_empty() {
            return Err(CoreError::BadBatch { rows: 0, reason: "empty request" });
        }
        let mut batch = std::mem::take(&mut self.sliced_batch);
        isa::pack_literals_sliced_into(rows, &mut batch);
        let mut out = std::mem::take(&mut self.sliced_scratch);
        let res = self.run_kernel_into(&batch, &mut out, kernel);
        self.sliced_batch = batch;
        self.sliced_scratch = out;
        res.map(|()| &self.sliced_scratch)
    }

    /// Convenience mirror of [`Self::run_rows`] on the sliced kernel:
    /// any row count, per-datapoint predictions.
    pub fn run_rows_sliced(&mut self, rows: &[Vec<u8>]) -> Result<Vec<usize>, CoreError> {
        let n = rows.len();
        let r = self.run_rows_sliced_ref(rows)?;
        Ok(r.preds[..n].iter().map(|&p| p as usize).collect())
    }

    /// [`Self::run_rows_sliced`] pinned to the sparse include-list
    /// kernel.
    pub fn run_rows_compressed(&mut self, rows: &[Vec<u8>]) -> Result<Vec<usize>, CoreError> {
        let n = rows.len();
        let r = self.run_rows_compressed_ref(rows)?;
        Ok(r.preds[..n].iter().map(|&p| p as usize).collect())
    }

    /// Resolve `Auto` to the program-time density decision.
    #[inline]
    fn resolve_kernel(&self, kernel: SlicedKernel) -> SlicedKernel {
        match kernel {
            SlicedKernel::Auto if self.use_compressed => SlicedKernel::Compressed,
            SlicedKernel::Auto => SlicedKernel::Sliced,
            pinned => pinned,
        }
    }

    /// True when `Auto` bulk runs ride the compressed kernel (decided
    /// once at program time from measured include density).
    pub fn uses_compressed_kernel(&self) -> bool {
        self.use_compressed
    }

    /// The compressed derivation of the programmed model — its measured
    /// `density`, `include_bytes()` and `avg_includes()` are the bench
    /// and resource-model context values.
    pub fn compressed_program(&self) -> &CompressedProgram {
        &self.compressed
    }

    fn accumulate(&mut self, c: &CycleStats) {
        self.stats.feature_load += c.feature_load;
        self.stats.execute += c.execute;
        self.stats.commit += c.commit;
        self.stats.argmax += c.argmax;
        self.stats.fifo += c.fifo;
    }

    /// Accumulate `batches` identical per-batch cycle records at once
    /// (the sliced bulk path's lifetime accounting).
    fn accumulate_scaled(&mut self, c: &CycleStats, batches: u64) {
        self.stats.feature_load += c.feature_load * batches;
        self.stats.execute += c.execute * batches;
        self.stats.commit += c.commit * batches;
        self.stats.argmax += c.argmax * batches;
        self.stats.fifo += c.fifo * batches;
    }

    fn record_trace(&mut self, i: usize, _clauses: u64, base: u64) {
        // Pipelined: instruction i issues at base+i and occupies stage s
        // at cycle base+i+s (1 instr/cycle steady state).  Iterative: the
        // four stages run back-to-back, 4 cycles per instruction.
        let issue = match self.cfg.pipeline {
            PipelineMode::Pipelined => base + i as u64,
            PipelineMode::Iterative => base + 4 * i as u64,
        };
        for (s, stage) in ["FETCH", "DECODE", "LIT-SEL", "CLAUSE-UPD"].iter().enumerate() {
            self.trace.push(TraceEvent { cycle: issue + s as u64, stage, instr: i });
        }
    }

    /// Seconds for `cycles` at this configuration's clock.
    pub fn seconds(&self, cycles: u64) -> f64 {
        cycles as f64 / (self.cfg.freq_mhz * 1e6)
    }

    /// Per-batch inference latency in microseconds for the last batch
    /// shape (excludes programming).
    pub fn batch_latency_us(&self, cycles: &CycleStats) -> f64 {
        self.seconds(cycles.total() - cycles.program) * 1e6
    }
}

/// argmax per row over class-major sums (`sums[class * padded + row]`),
/// first-max tie-break like [`argmax_lanes`].  Shared by the single-
/// and multi-core sliced paths so their predictions can never diverge.
pub fn argmax_rows(sums: &[i32], padded: usize, classes: usize, preds: &mut Vec<u8>) {
    preds.clear();
    preds.resize(padded, 0);
    for (row, p) in preds.iter_mut().enumerate() {
        let mut best = 0usize;
        for class in 1..classes {
            if sums[class * padded + row] > sums[best * padded + row] {
                best = class;
            }
        }
        *p = best as u8;
    }
}

/// argmax per bit lane (first-max tie-break, like jnp.argmax).
pub fn argmax_lanes(sums: &[[i32; 32]]) -> [u8; 32] {
    let mut preds = [0u8; 32];
    for (b, p) in preds.iter_mut().enumerate() {
        let mut best = 0usize;
        for (m, row) in sums.iter().enumerate() {
            if row[b] > sums[best][b] {
                best = m;
            }
        }
        *p = best as u8;
    }
    preds
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::synth::SynthSpec;
    use crate::tm::{model::TMModel, reference};
    use crate::TMShape;

    fn trained_tiny() -> (TMModel, crate::datasets::synth::Dataset) {
        let shape = TMShape::synthetic(12, 3, 8);
        let data = SynthSpec::new(12, 3, 256).noise(0.05).seed(21).generate();
        let model = crate::trainer::train_model(&shape, &data, 4, 2);
        (model, data)
    }

    #[test]
    fn core_matches_dense_reference() {
        let (model, data) = trained_tiny();
        let mut core = Core::new(AccelConfig::base());
        core.program_model(&model).unwrap();
        let rows: Vec<Vec<u8>> = data.xs[..32].to_vec();
        let preds = core.run_rows(&rows).unwrap();
        for (x, &p) in rows.iter().zip(&preds) {
            let lits = reference::literals_from_features(x);
            assert_eq!(p, reference::predict_dense(&model, &lits));
        }
    }

    #[test]
    fn unprogrammed_core_errors() {
        let mut core = Core::new(AccelConfig::base());
        assert!(matches!(
            core.run_batch(&[0u32; 4]),
            Err(CoreError::NotProgrammed)
        ));
    }

    #[test]
    fn stream_program_then_infer() {
        let (model, data) = trained_tiny();
        let mut core = Core::new(AccelConfig::base());
        let codec = core.codec;
        let instrs = isa::encode(&model);

        let mut words = Vec::new();
        words.extend(
            codec
                .instruction_header(model.shape.classes, model.shape.clauses, instrs.len())
                .unwrap(),
        );
        words.extend(codec.pack_instructions(&instrs));
        let packed = isa::pack_features(&data.xs[..32].to_vec());
        words.extend(codec.feature_header(packed.len(), 1).unwrap());
        words.extend(codec.pack_feature_words(&packed));

        let results = core.feed_stream(&words).unwrap();
        assert_eq!(results.len(), 1);
        // Same as direct programming.
        let mut direct = Core::new(AccelConfig::base());
        direct.program_model(&model).unwrap();
        let d = direct.run_batch(&packed).unwrap();
        assert_eq!(results[0].preds, d.preds);
        assert_eq!(results[0].class_sums, d.class_sums);
    }

    #[test]
    fn reprogramming_replaces_model() {
        // Runtime tunability: same core, two different models, no rebuild.
        let (model_a, data) = trained_tiny();
        let shape_b = TMShape::synthetic(12, 3, 4);
        let data_b = SynthSpec::new(12, 3, 128).noise(0.05).seed(77).generate();
        let model_b = crate::trainer::train_model(&shape_b, &data_b, 4, 3);

        let mut core = Core::new(AccelConfig::base());
        core.program_model(&model_a).unwrap();
        let rows: Vec<Vec<u8>> = data.xs[..8].to_vec();
        let a = core.run_rows(&rows).unwrap();

        core.program_model(&model_b).unwrap();
        assert_eq!(core.instruction_count(), isa::encode(&model_b).len());
        core.program_model(&model_a).unwrap();
        let a2 = core.run_rows(&rows).unwrap();
        assert_eq!(a, a2, "reprogramming must be idempotent");
    }

    #[test]
    fn cycle_model_pipelined_vs_iterative() {
        let (model, data) = trained_tiny();
        let packed = isa::pack_features(&data.xs[..32].to_vec());

        let mut pipe = Core::new(AccelConfig::base());
        pipe.program_model(&model).unwrap();
        let rp = pipe.run_batch(&packed).unwrap();

        let mut iter = Core::new(AccelConfig::base().with_pipeline(PipelineMode::Iterative));
        iter.program_model(&model).unwrap();
        let ri = iter.run_batch(&packed).unwrap();

        let n = pipe.instruction_count() as u64;
        assert_eq!(rp.cycles.execute, 3 + n);
        assert_eq!(ri.cycles.execute, 4 * n);
        // Same answers, different time.
        assert_eq!(rp.preds, ri.preds);
        assert!(ri.cycles.total() > rp.cycles.total());
    }

    #[test]
    fn cycle_accounting_accumulates() {
        let (model, data) = trained_tiny();
        let mut core = Core::new(AccelConfig::base());
        core.program_model(&model).unwrap();
        let packed = isa::pack_features(&data.xs[..32].to_vec());
        let r1 = core.run_batch(&packed).unwrap();
        let r2 = core.run_batch(&packed).unwrap();
        assert_eq!(r1.cycles, r2.cycles);
        assert_eq!(core.batches_run, 2);
        assert_eq!(core.stats.execute, r1.cycles.execute * 2);
        assert!(core.stats.program > 0);
    }

    #[test]
    fn batch_equals_32_singles_through_core() {
        // The paper's batching claim: one batched pass == 32 single runs.
        let (model, data) = trained_tiny();
        let mut core = Core::new(AccelConfig::base());
        core.program_model(&model).unwrap();
        let rows: Vec<Vec<u8>> = data.xs[..32].to_vec();
        let batched = core.run_rows(&rows).unwrap();
        for (i, row) in rows.iter().enumerate() {
            let single = core.run_rows(&[row.clone()]).unwrap();
            assert_eq!(single[0], batched[i], "dp {i}");
        }
    }

    #[test]
    fn run_batches_matches_repeated_run_batch() {
        let (model, data) = trained_tiny();
        let packed_a = isa::pack_features(&data.xs[..32].to_vec());
        let packed_b = isa::pack_features(&data.xs[32..64].to_vec());

        let mut one = Core::new(AccelConfig::base());
        one.program_model(&model).unwrap();
        let ra = one.run_batch(&packed_a).unwrap();
        let rb = one.run_batch(&packed_b).unwrap();

        let mut many = Core::new(AccelConfig::base());
        many.program_model(&model).unwrap();
        let rs = many.run_batches(&[&packed_a, &packed_b]).unwrap();
        assert_eq!(rs.len(), 2);
        assert_eq!(rs[0], ra);
        assert_eq!(rs[1], rb);
        assert_eq!(one.stats, many.stats);
        assert_eq!(many.batches_run, 2);
    }

    #[test]
    fn run_batch_into_reuses_result_buffers() {
        let (model, data) = trained_tiny();
        let mut core = Core::new(AccelConfig::base());
        core.program_model(&model).unwrap();
        let packed = isa::pack_features(&data.xs[..32].to_vec());

        let fresh = core.run_batch(&packed).unwrap();
        let mut reused = BatchResult::default();
        core.run_batch_into(&packed, &mut reused).unwrap();
        assert_eq!(reused, fresh);
        // Second pass into the same result: identical again, in place.
        core.run_batch_into(&packed, &mut reused).unwrap();
        assert_eq!(reused, fresh);
    }

    #[test]
    fn failed_program_unprograms_core() {
        // A corrupt stream mid-predecode must not leave a truncated
        // walk behind: the core reports NotProgrammed afterwards.
        let (model, data) = trained_tiny();
        let mut core = Core::new(AccelConfig::base());
        core.program_model(&model).unwrap();
        let bad = vec![
            Instr::new(false, false, false, 0, false),
            // E toggles with only 1 class in the header: ClassOverrun.
            Instr::new(false, true, true, 0, false),
        ];
        assert!(core.program(1, 1, &bad).is_err());
        let packed = isa::pack_features(&data.xs[..32].to_vec());
        assert!(matches!(
            core.run_batch(&packed),
            Err(CoreError::NotProgrammed)
        ));
        // A good reprogram fully recovers.
        core.program_model(&model).unwrap();
        assert!(core.run_batch(&packed).is_ok());
    }

    #[test]
    fn run_batches_unprogrammed_errors() {
        let mut core = Core::new(AccelConfig::base());
        let packed = [0u32; 4];
        assert!(matches!(
            core.run_batches(&[&packed]),
            Err(CoreError::NotProgrammed)
        ));
    }

    #[test]
    fn fifo_receives_batch() {
        let (model, data) = trained_tiny();
        let mut core = Core::new(AccelConfig::base());
        core.program_model(&model).unwrap();
        let packed = isa::pack_features(&data.xs[..32].to_vec());
        core.run_batch(&packed).unwrap();
        assert_eq!(core.fifo.len(), 32);
        let drained = core.fifo.drain();
        assert_eq!(drained.len(), 32);
    }

    #[test]
    fn model_too_big_for_memory_rejected() {
        let mut core = Core::new(AccelConfig::base().with_depths(4, 2048));
        let (model, _) = trained_tiny();
        let err = core.program_model(&model);
        assert!(matches!(err, Err(CoreError::Mem(_))));
    }

    #[test]
    fn sliced_path_matches_per_batch_walk_exactly() {
        // Same rows through run_batch_into (32-row chunks) and through
        // the sliced kernel: preds, per-row sums, simulated cycles,
        // lifetime counters and FIFO contents must all agree.
        let (model, data) = trained_tiny();
        let rows: Vec<Vec<u8>> = (0..100).map(|i| data.xs[i % data.len()].clone()).collect();

        let mut soa = Core::new(AccelConfig::base());
        soa.program_model(&model).unwrap();
        let mut per_batch = Vec::new();
        for chunk in rows.chunks(32) {
            per_batch.push(soa.run_batch(&isa::pack_features(chunk)).unwrap());
        }

        let mut sliced = Core::new(AccelConfig::base());
        sliced.program_model(&model).unwrap();
        // Clone out of the scratch so the core is free for the
        // lifetime-counter asserts below.
        let r = sliced.run_rows_sliced_ref(&rows).unwrap().clone();
        assert_eq!(r.rows, 100);
        assert_eq!(r.batches, 4);
        for (row, _) in rows.iter().enumerate() {
            let b = &per_batch[row / 32];
            let lane = row % 32;
            assert_eq!(r.preds[row], b.preds[lane], "row {row}: preds");
            for class in 0..model.shape.classes {
                assert_eq!(
                    r.class_sum(class, row),
                    b.class_sums[class][lane],
                    "row {row} class {class}: sums"
                );
            }
        }
        assert_eq!(r.batch_cycles, per_batch[0].cycles);
        assert_eq!(r.total_cycles(), per_batch.iter().map(|b| b.cycles.total()).sum::<u64>());
        // Lifetime accounting and FIFO contents keep parity (FIFO
        // includes the final batch's padding lanes either way).
        assert_eq!(sliced.stats, soa.stats);
        assert_eq!(sliced.batches_run, soa.batches_run);
        assert_eq!(sliced.fifo.drain(), soa.fifo.drain());

        // The convenience wrapper clips the ragged tail.
        let preds = sliced.run_rows_sliced(&rows).unwrap();
        assert_eq!(preds.len(), 100);
        let soa_preds: Vec<usize> = (0..100)
            .map(|row| per_batch[row / 32].preds[row % 32] as usize)
            .collect();
        assert_eq!(preds, soa_preds);
    }

    #[test]
    fn sliced_path_errors_match_the_batch_walk() {
        let (model, data) = trained_tiny();
        let mut core = Core::new(AccelConfig::base());
        // Not programmed.
        assert!(matches!(
            core.run_rows_sliced(&data.xs[..4].to_vec()),
            Err(CoreError::NotProgrammed)
        ));
        core.program_model(&model).unwrap();
        // Empty requests are typed errors, not pack panics.
        assert!(matches!(
            core.run_rows_sliced(&[]),
            Err(CoreError::BadBatch { rows: 0, .. })
        ));
        // Too few features for the programmed walk: same OffsetOverrun
        // the 32-lane path raises.
        let narrow = vec![vec![0u8; 2]; 8];
        assert!(matches!(
            core.run_rows_sliced(&narrow),
            Err(CoreError::Isa(isa::IsaError::OffsetOverrun { .. }))
        ));
        // A batch wider than Feature Memory: same capacity error.
        let mut shallow = Core::new(AccelConfig::base().with_depths(8192, 4));
        shallow.program_model(&model).unwrap();
        let wide = vec![vec![0u8; 12]; 8];
        assert!(matches!(
            shallow.run_rows_sliced(&wide),
            Err(CoreError::Mem(MemError::FeatureOverflow { .. }))
        ));
        // Errors leave the scratch reusable: a good run still works.
        assert_eq!(core.run_rows_sliced(&data.xs[..65].to_vec()).unwrap().len(), 65);
    }

    #[test]
    fn latency_scales_with_frequency() {
        let mut base = AccelConfig::base();
        base.freq_mhz = 200.0;
        let core200 = Core::new(base.clone());
        base.freq_mhz = 100.0;
        let core100 = Core::new(base);
        assert!((core100.seconds(1000) - 2.0 * core200.seconds(1000)).abs() < 1e-12);
    }
}
