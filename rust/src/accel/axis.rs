//! AXI-Stream interface model (the S and M configurations' front-end).
//!
//! The paper's single- and multi-core builds sit behind AXIS so a host
//! processor can pre-process and stream data in (Fig 4.1, Fig 7).  This
//! model accounts *beats* (one word transfer per cycle when both READY
//! and VALID) with a bounded skid FIFO, and implements the Fig 7
//! splitter: instruction traffic is routed to one core's port by class
//! range, feature traffic is broadcast to all ports.
//!
//! It gives the coordinator backpressure visibility (stall cycles) and
//! makes the multi-core programming path explicit — per-core instruction
//! streams really are produced by splitting one encoded model stream.

use crate::isa::Instr;
use crate::tm::model::TMModel;

/// One AXIS port with a skid buffer of `depth` words.
#[derive(Debug, Clone)]
pub struct AxisPort {
    pub depth: usize,
    queue: std::collections::VecDeque<u64>,
    /// Beats accepted.
    pub beats: u64,
    /// Cycles the sender was stalled on a full buffer.
    pub stall_cycles: u64,
}

impl AxisPort {
    pub fn new(depth: usize) -> Self {
        AxisPort {
            depth,
            queue: std::collections::VecDeque::with_capacity(depth),
            beats: 0,
            stall_cycles: 0,
        }
    }

    /// Offer one word; models a consumer that drains one word per cycle
    /// (the accelerator's 1 word/cycle stream front-end): a full queue
    /// stalls the producer for the cycles needed to free space.
    pub fn push(&mut self, word: u64) {
        if self.queue.len() == self.depth {
            // Consumer drains one word per cycle; producer waits one.
            self.stall_cycles += 1;
            self.queue.pop_front();
        }
        self.queue.push_back(word);
        self.beats += 1;
    }

    pub fn drain(&mut self) -> Vec<u64> {
        self.queue.drain(..).collect()
    }

    pub fn len(&self) -> usize {
        self.queue.len()
    }
    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// Total transfer cycles for everything pushed so far.
    pub fn transfer_cycles(&self) -> u64 {
        self.beats + self.stall_cycles
    }
}

/// The Fig 7 AXIS splitter: one inbound stream, N core ports.
pub struct AxisSplitter {
    pub ports: Vec<AxisPort>,
}

impl AxisSplitter {
    pub fn new(n_ports: usize, skid_depth: usize) -> Self {
        AxisSplitter {
            ports: (0..n_ports).map(|_| AxisPort::new(skid_depth)).collect(),
        }
    }

    /// Split a model's instruction stream across class partitions:
    /// port i receives the full (header + payload) programming stream of
    /// its class slice.  Returns the per-port instruction streams.
    pub fn split_program(
        &mut self,
        model: &TMModel,
        assign: &[(usize, usize)],
        codec: &super::stream::StreamCodec,
    ) -> Result<Vec<Vec<Instr>>, super::stream::StreamError> {
        assert_eq!(assign.len(), self.ports.len());
        let mut streams = Vec::with_capacity(assign.len());
        for (port, &(s, e)) in self.ports.iter_mut().zip(assign) {
            if s == e {
                streams.push(Vec::new());
                continue;
            }
            let slice = model.slice_classes(s..e);
            let instrs = crate::isa::encode(&slice);
            let header =
                codec.instruction_header(slice.shape.classes, slice.shape.clauses, instrs.len())?;
            for w in header {
                port.push(w);
            }
            for w in codec.pack_instructions(&instrs) {
                port.push(w);
            }
            streams.push(instrs);
        }
        Ok(streams)
    }

    /// Broadcast one feature batch to every active port.
    pub fn broadcast_features(
        &mut self,
        packed: &[u32],
        codec: &super::stream::StreamCodec,
    ) -> Result<(), super::stream::StreamError> {
        for port in &mut self.ports {
            let header = codec.feature_header(packed.len(), 1)?;
            for w in header {
                port.push(w);
            }
            for w in codec.pack_feature_words(packed) {
                port.push(w);
            }
        }
        Ok(())
    }

    /// Worst-port transfer cycles (ports fill in parallel on the real
    /// interconnect; the slowest port gates the batch).
    pub fn max_transfer_cycles(&self) -> u64 {
        self.ports.iter().map(|p| p.transfer_cycles()).max().unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::multicore::MultiCore;
    use crate::accel::stream::{HeaderWidth, StreamCodec};
    use crate::datasets::synth::SynthSpec;
    use crate::TMShape;

    fn trained() -> TMModel {
        let shape = TMShape::synthetic(12, 4, 8);
        let data = SynthSpec::new(12, 4, 192).noise(0.05).seed(3).generate();
        crate::trainer::train_model(&shape, &data, 3, 1)
    }

    #[test]
    fn port_counts_beats() {
        let mut p = AxisPort::new(4);
        for w in 0..3u64 {
            p.push(w);
        }
        assert_eq!(p.beats, 3);
        assert_eq!(p.stall_cycles, 0);
        assert_eq!(p.drain(), vec![0, 1, 2]);
    }

    #[test]
    fn full_port_stalls_producer() {
        let mut p = AxisPort::new(2);
        for w in 0..5u64 {
            p.push(w);
        }
        assert_eq!(p.beats, 5);
        assert_eq!(p.stall_cycles, 3);
        assert_eq!(p.transfer_cycles(), 8);
    }

    #[test]
    fn splitter_partitions_instructions_by_class() {
        let model = trained();
        let per_class: Vec<usize> = model
            .includes_per_class()
            .into_iter()
            .map(|v| if v == 0 { 2 } else { v })
            .collect();
        let assign = MultiCore::partition(&per_class, 2);
        let codec = StreamCodec::new(HeaderWidth::W32);
        let mut sp = AxisSplitter::new(2, 64);
        let streams = sp.split_program(&model, &assign, &codec).unwrap();
        let total: usize = streams.iter().map(|s| s.len()).sum();
        assert_eq!(total, crate::isa::instruction_count(&model));
        // Port streams decode back to the class slices.
        for (stream, &(s, e)) in streams.iter().zip(&assign) {
            let slice = model.slice_classes(s..e);
            let decoded = crate::isa::encoder::decode_clauses(
                stream,
                slice.shape.literals(),
                slice.shape.classes,
            )
            .unwrap();
            assert_eq!(decoded.len(), e - s);
        }
    }

    #[test]
    fn broadcast_reaches_all_ports_equally() {
        let codec = StreamCodec::new(HeaderWidth::W32);
        let mut sp = AxisSplitter::new(3, 1024);
        sp.broadcast_features(&[1, 2, 3, 4], &codec).unwrap();
        let beats: Vec<u64> = sp.ports.iter().map(|p| p.beats).collect();
        assert_eq!(beats, vec![6, 6, 6]); // 2 header + 4 payload each
    }

    #[test]
    fn split_streams_program_real_cores() {
        // The AXIS path produces streams that actually program cores and
        // reproduce single-core predictions.
        let model = trained();
        let per_class: Vec<usize> = model
            .includes_per_class()
            .into_iter()
            .map(|v| if v == 0 { 2 } else { v })
            .collect();
        let assign = MultiCore::partition(&per_class, 2);
        let codec = StreamCodec::new(HeaderWidth::W32);
        let mut sp = AxisSplitter::new(2, 4096);
        sp.split_program(&model, &assign, &codec).unwrap();

        let data = SynthSpec::new(12, 4, 64).seed(9).generate();
        let packed = crate::isa::pack_features(&data.xs[..32].to_vec());
        sp.broadcast_features(&packed, &codec).unwrap();

        let mut sums = vec![[0i32; 32]; model.shape.classes];
        for (port, &(s, e)) in sp.ports.iter_mut().zip(&assign) {
            let words = port.drain();
            let mut core =
                crate::accel::Core::new(crate::accel::core::AccelConfig::multicore_core());
            let results = core.feed_stream(&words).unwrap();
            assert_eq!(results.len(), 1);
            for (local, class) in (s..e).enumerate() {
                sums[class] = results[0].class_sums[local];
            }
        }
        // Merge equals a directly-programmed single core.
        let mut single = crate::accel::Core::new(
            crate::accel::core::AccelConfig::base().with_depths(8192, 2048),
        );
        single.program_model(&model).unwrap();
        let r = single.run_batch(&packed).unwrap();
        assert_eq!(sums, r.class_sums);
    }
}
