//! Host-side batch scheduler: drives multi-batch, multi-core serving
//! throughput over the simulators.
//!
//! The coordinator's request loop serves one 32-lane batch at a time;
//! this module is the throughput-oriented complement for offline sweeps
//! and bulk serving: pack an arbitrary row stream into bit-sliced
//! batches once, then drive a whole stream through
//! [`Core::run_batches`] / [`MultiCore::run_batches`] so per-batch
//! setup (thread spawn for the multi-core path, result allocation,
//! bounds checks) is amortized across the stream.  Wall-clock and
//! simulated cycles are reported side by side — the host should run
//! "as fast as the hardware allows", the cycle model stays the
//! hardware's.

use super::core::{BatchResult, Core, CoreError, SlicedKernel};
use super::multicore::{MultiBatchResult, MultiCore};
use crate::isa;

/// Throughput accounting for one scheduled stream.
#[derive(Debug, Clone, Default)]
pub struct StreamStats {
    /// 32-lane batches executed.
    pub batches: u64,
    /// Datapoints classified (last batch may be ragged).
    pub inferences: u64,
    /// Simulated accelerator cycles (per-batch totals summed; for the
    /// multi-core engine this is the parallel `batch_cycles`).
    pub simulated_cycles: u64,
    /// Host wall-clock for the whole stream.
    pub wall: std::time::Duration,
}

impl StreamStats {
    /// Host batches per second.
    pub fn host_batches_per_s(&self) -> f64 {
        self.batches as f64 / self.wall.as_secs_f64().max(1e-12)
    }

    /// Host datapoint classifications per second.
    pub fn host_inferences_per_s(&self) -> f64 {
        self.inferences as f64 / self.wall.as_secs_f64().max(1e-12)
    }

    /// Simulated accelerator busy-time in microseconds.
    pub fn simulated_us(&self, freq_mhz: f64) -> f64 {
        self.simulated_cycles as f64 / freq_mhz
    }
}

/// Validate a request's rows before packing.  `isa::pack_literals`
/// panics on empty, >32-row and ragged-width input — a serving front
/// end must reject those as typed errors instead of dying, so every
/// request-path entry point calls this first (`max_rows` is 32 for a
/// single-batch call, `usize::MAX` for the chunking bulk paths).
pub fn validate_rows(rows: &[Vec<u8>], max_rows: usize) -> Result<(), CoreError> {
    if rows.is_empty() {
        return Err(CoreError::BadBatch { rows: 0, reason: "empty request" });
    }
    if rows.len() > max_rows {
        return Err(CoreError::BadBatch {
            rows: rows.len(),
            reason: "more rows than batch lanes",
        });
    }
    let width = rows[0].len();
    if rows.iter().any(|r| r.len() != width) {
        return Err(CoreError::BadBatch {
            rows: rows.len(),
            reason: "ragged feature widths",
        });
    }
    Ok(())
}

/// Pack a row stream into 32-lane bit-sliced batches (Feature Memory
/// layout) — done once, up front, off the serving hot path.
pub fn pack_stream(rows: &[Vec<u8>]) -> Vec<Vec<u32>> {
    rows.chunks(32).map(isa::pack_features).collect()
}

/// Borrow a packed stream as the slice-of-slices the engines take.
pub fn as_batch_refs(batches: &[Vec<u32>]) -> Vec<&[u32]> {
    batches.iter().map(Vec::as_slice).collect()
}

/// Drive a packed batch stream through a single core.
pub fn run_core_stream(
    core: &mut Core,
    batches: &[Vec<u32>],
    inferences: u64,
) -> Result<(Vec<BatchResult>, StreamStats), CoreError> {
    let refs = as_batch_refs(batches);
    let t0 = std::time::Instant::now();
    let results = core.run_batches(&refs)?;
    let wall = t0.elapsed();
    let stats = StreamStats {
        batches: results.len() as u64,
        inferences,
        simulated_cycles: results.iter().map(|r| r.cycles.total()).sum(),
        wall,
    };
    Ok((results, stats))
}

/// Drive a packed batch stream through a multi-core engine (class
/// parallelism across host threads per [`MultiCore::parallel`]).
pub fn run_multicore_stream(
    mc: &mut MultiCore,
    batches: &[Vec<u32>],
    inferences: u64,
) -> Result<(Vec<MultiBatchResult>, StreamStats), CoreError> {
    let refs = as_batch_refs(batches);
    let t0 = std::time::Instant::now();
    let results = mc.run_batches(&refs)?;
    let wall = t0.elapsed();
    let stats = StreamStats {
        batches: results.len() as u64,
        inferences,
        simulated_cycles: results.iter().map(|r| r.batch_cycles).sum(),
        wall,
    };
    Ok((results, stats))
}

/// Batches per `MultiCore::run_batches` call in the bulk-classify
/// path: large enough to amortize the per-call thread spawn, small
/// enough to keep retained results O(chunk), not O(stream).
pub const MULTICORE_CHUNK_BATCHES: usize = 256;

/// Row count at and above which the bulk classify paths switch from
/// the 32-lane per-batch walk to the 64-lane bit-sliced kernel
/// (§Bit-sliced in EXPERIMENTS.md).  Below it the transpose is not
/// worth setting up; above it one `u64` op does useful work for 64
/// rows and the kernel streams contiguous literal planes.  Results are
/// byte-identical either way (enforced by `tests/engine_equivalence.rs`
/// §sliced), so the threshold is purely a host-speed policy.
pub const SLICED_MIN_ROWS: usize = 256;

/// Include-density ceiling below which [`SlicedKernel::Auto`] bulk runs
/// pick the compressed include-list kernel over the dense 64-lane plane
/// walk (§Compressed in EXPERIMENTS.md).  Density is MEASURED at
/// derivation time (kept include entries over the underived literal
/// space — see `isa::CompressedProgram::density`), so the decision is
/// per-model, made once per (re)program, and free on the request path.
/// At 5% the average clause touches a handful of planes, where the
/// compressed kernel's fused single-include commits and early exits
/// beat the dense walk's fill + AND + commit passes; denser programs
/// stream planes better through the sliced walk.  Both kernels are
/// byte-identical in every observable, so this is purely a host-speed
/// policy — never a correctness or cycle-model decision.
pub const COMPRESSED_MAX_DENSITY: f64 = 0.05;

/// Rows per sliced pass: bounds the O(classes x rows) sums scratch the
/// same way [`MULTICORE_CHUNK_BATCHES`] bounds retained batch results,
/// and (being a multiple of 64) keeps every chunk boundary aligned to
/// whole 64-row slices — no partially-filled slice except the stream's
/// final one.
pub const SLICED_CHUNK_ROWS: usize = MULTICORE_CHUNK_BATCHES * 32;

/// Bulk-classify rows on a single core.  The serving-example entry
/// point: picks the 64-lane bit-sliced kernel automatically at
/// [`SLICED_MIN_ROWS`] and above, the 32-lane per-batch walk below
/// (byte-identical results, different host speed).
pub fn classify_rows_core(
    core: &mut Core,
    rows: &[Vec<u8>],
) -> Result<(Vec<usize>, StreamStats), CoreError> {
    if rows.len() >= SLICED_MIN_ROWS {
        let (preds, _margins, stats) = sliced_run(core, rows, false, SlicedKernel::Auto)?;
        Ok((preds, stats))
    } else {
        classify_rows_core_soa(core, rows)
    }
}

/// The 32-lane per-batch path, pinnable explicitly (the hotpath bench
/// pins it for before/after comparisons): pack, stream, unpack.
/// Memory stays O(1) per batch: one reused [`BatchResult`] scratch,
/// predictions appended as each batch completes.
pub fn classify_rows_core_soa(
    core: &mut Core,
    rows: &[Vec<u8>],
) -> Result<(Vec<usize>, StreamStats), CoreError> {
    if rows.is_empty() {
        return Ok((Vec::new(), StreamStats::default()));
    }
    validate_rows(rows, usize::MAX)?;
    let batches = pack_stream(rows);
    let t0 = std::time::Instant::now();
    let mut preds = Vec::with_capacity(rows.len());
    let mut scratch = BatchResult::default();
    let mut cycles = 0u64;
    for b in &batches {
        core.run_batch_into(b, &mut scratch)?;
        take_preds(&mut preds, &scratch.preds, rows.len());
        cycles += scratch.cycles.total();
    }
    let stats = StreamStats {
        batches: batches.len() as u64,
        inferences: rows.len() as u64,
        simulated_cycles: cycles,
        wall: t0.elapsed(),
    };
    Ok((preds, stats))
}

/// The 64-lane bit-sliced path, pinnable explicitly: the rows are
/// transposed once per [`SLICED_CHUNK_ROWS`]-sized chunk into 64-row
/// literal planes and each clause evaluates 64 rows per bitwise op.
/// All scratch (transpose planes, clause accumulator, per-row sums)
/// lives in the [`Core`] and is reused — no per-batch allocation.
pub fn classify_rows_core_sliced(
    core: &mut Core,
    rows: &[Vec<u8>],
) -> Result<(Vec<usize>, StreamStats), CoreError> {
    let (preds, _margins, stats) = sliced_run(core, rows, false, SlicedKernel::Sliced)?;
    Ok((preds, stats))
}

/// The compressed include-list path, pinnable explicitly (the hotpath
/// bench pins it against [`classify_rows_core_sliced`] for the sparse
/// speedup ratio): same transpose and chunking, sparse gather-AND walk.
/// Byte-identical results — the compressed derivation never prunes.
pub fn classify_rows_core_compressed(
    core: &mut Core,
    rows: &[Vec<u8>],
) -> Result<(Vec<usize>, StreamStats), CoreError> {
    let (preds, _margins, stats) = sliced_run(core, rows, false, SlicedKernel::Compressed)?;
    Ok((preds, stats))
}

/// Borrowed view of one sliced chunk's outputs: lets [`sliced_run`]
/// drive the single- and multi-core engines through one loop, so their
/// StreamStats accounting can never desynchronize.
struct SlicedView<'a> {
    sums: &'a [i32],
    padded: usize,
    rows: usize,
    preds: &'a [u8],
    batches: u64,
    cycles: u64,
}

/// An engine the sliced bulk scheduler can drive chunk by chunk.
/// `kernel` selects the 64-lane walk ([`SlicedKernel`]); `Auto`
/// resolves per engine (per core on the multi-core engine) to the
/// program-time density decision.
trait SlicedEngine {
    fn run_sliced_chunk(
        &mut self,
        chunk: &[Vec<u8>],
        kernel: SlicedKernel,
    ) -> Result<SlicedView<'_>, CoreError>;
}

impl SlicedEngine for Core {
    fn run_sliced_chunk(
        &mut self,
        chunk: &[Vec<u8>],
        kernel: SlicedKernel,
    ) -> Result<SlicedView<'_>, CoreError> {
        let r = self.run_rows_kernel_ref(chunk, kernel)?;
        Ok(SlicedView {
            sums: &r.class_sums,
            padded: r.padded_rows,
            rows: r.rows,
            preds: &r.preds,
            batches: r.batches,
            cycles: r.total_cycles(),
        })
    }
}

impl SlicedEngine for MultiCore {
    fn run_sliced_chunk(
        &mut self,
        chunk: &[Vec<u8>],
        kernel: SlicedKernel,
    ) -> Result<SlicedView<'_>, CoreError> {
        let r = self.run_rows_kernel_ref(chunk, kernel)?;
        Ok(SlicedView {
            sums: &r.class_sums,
            padded: r.padded_rows,
            rows: r.rows,
            preds: &r.preds,
            batches: r.batches,
            cycles: r.total_cycles(),
        })
    }
}

/// Shared body of every sliced bulk path (preds-only and margins-aware
/// — the margin scan is the only difference): 64-row-aligned chunks
/// through the engine's chosen 64-lane kernel, preds/margins appended
/// per chunk, StreamStats accumulated.
fn sliced_run<E: SlicedEngine>(
    engine: &mut E,
    rows: &[Vec<u8>],
    want_margins: bool,
    kernel: SlicedKernel,
) -> Result<(Vec<usize>, Vec<i32>, StreamStats), CoreError> {
    if rows.is_empty() {
        return Ok((Vec::new(), Vec::new(), StreamStats::default()));
    }
    validate_rows(rows, usize::MAX)?;
    let t0 = std::time::Instant::now();
    let mut preds = Vec::with_capacity(rows.len());
    let mut margins = Vec::with_capacity(if want_margins { rows.len() } else { 0 });
    let mut batches = 0u64;
    let mut cycles = 0u64;
    for chunk in rows.chunks(SLICED_CHUNK_ROWS) {
        let v = engine.run_sliced_chunk(chunk, kernel)?;
        extend_from_sliced(
            &mut preds,
            want_margins.then_some(&mut margins),
            v.sums,
            v.padded,
            v.rows,
            v.preds,
        );
        batches += v.batches;
        cycles += v.cycles;
    }
    let stats = StreamStats {
        batches,
        inferences: rows.len() as u64,
        simulated_cycles: cycles,
        wall: t0.elapsed(),
    };
    Ok((preds, margins, stats))
}

/// Append one sliced run's per-row predictions (and, when asked,
/// confidence margins) to the output vectors.  Margin semantics are
/// identical to [`margins_from_sums`]: winner minus runner-up, the
/// winning sum itself for a single class.
fn extend_from_sliced(
    preds: &mut Vec<usize>,
    margins: Option<&mut Vec<i32>>,
    sums: &[i32],
    padded: usize,
    rows: usize,
    row_preds: &[u8],
) {
    preds.extend(row_preds[..rows].iter().map(|&p| p as usize));
    if let Some(margins) = margins {
        let classes = sums.len() / padded.max(1);
        for row in 0..rows {
            let (mut best, mut second) = (i32::MIN, i32::MIN);
            for class in 0..classes {
                let v = sums[class * padded + row];
                if v > best {
                    second = best;
                    best = v;
                } else if v > second {
                    second = v;
                }
            }
            margins.push(if second == i32::MIN { best } else { best - second });
        }
    }
}

/// Bulk-classify rows on a multi-core engine: the sliced kernel at
/// [`SLICED_MIN_ROWS`] and above (chunk boundaries aligned to 64-row
/// slices), the 32-lane chunked stream below.
pub fn classify_rows_multicore(
    mc: &mut MultiCore,
    rows: &[Vec<u8>],
) -> Result<(Vec<usize>, StreamStats), CoreError> {
    if rows.len() >= SLICED_MIN_ROWS {
        let (preds, _margins, stats) = sliced_run(mc, rows, false, SlicedKernel::Auto)?;
        return Ok((preds, stats));
    }
    classify_rows_multicore_soa(mc, rows)
}

/// The compressed include-list path on a multi-core engine, pinnable
/// explicitly for benches — every class-partitioned core walks its
/// include lists instead of dense planes.
pub fn classify_rows_multicore_compressed(
    mc: &mut MultiCore,
    rows: &[Vec<u8>],
) -> Result<(Vec<usize>, StreamStats), CoreError> {
    let (preds, _margins, stats) = sliced_run(mc, rows, false, SlicedKernel::Compressed)?;
    Ok((preds, stats))
}

/// The 32-lane multi-core bulk path: the stream is driven in
/// [`MULTICORE_CHUNK_BATCHES`]-sized chunks — thread-spawn cost is
/// amortized within each chunk while retained results stay bounded by
/// the chunk, not the whole stream.
pub fn classify_rows_multicore_soa(
    mc: &mut MultiCore,
    rows: &[Vec<u8>],
) -> Result<(Vec<usize>, StreamStats), CoreError> {
    if rows.is_empty() {
        return Ok((Vec::new(), StreamStats::default()));
    }
    validate_rows(rows, usize::MAX)?;
    let batches = pack_stream(rows);
    let t0 = std::time::Instant::now();
    let mut preds = Vec::with_capacity(rows.len());
    let mut n_batches = 0u64;
    let mut cycles = 0u64;
    for chunk in batches.chunks(MULTICORE_CHUNK_BATCHES) {
        let refs = as_batch_refs(chunk);
        for r in mc.run_batches(&refs)? {
            take_preds(&mut preds, &r.preds, rows.len());
            cycles += r.batch_cycles;
            n_batches += 1;
        }
    }
    let stats = StreamStats {
        batches: n_batches,
        inferences: rows.len() as u64,
        simulated_cycles: cycles,
        wall: t0.elapsed(),
    };
    Ok((preds, stats))
}

/// Append one batch's 32-lane predictions, clipping the ragged tail.
fn take_preds(out: &mut Vec<usize>, preds: &[u8; 32], n: usize) {
    let take = (n - out.len()).min(32);
    out.extend(preds[..take].iter().map(|&p| p as usize));
}

/// Per-lane confidence margin: winning class sum minus runner-up.  A
/// drifting input distribution collapses this *before* labels arrive —
/// the autotuner's and the canary gate's label-free signal.  With a
/// single class the margin is the winning sum itself.
pub fn margins_from_sums(sums: &[[i32; 32]], n: usize) -> Vec<i32> {
    (0..n.min(32))
        .map(|b| {
            let (mut best, mut second) = (i32::MIN, i32::MIN);
            for row in sums {
                let v = row[b];
                if v > best {
                    second = best;
                    best = v;
                } else if v > second {
                    second = v;
                }
            }
            if second == i32::MIN {
                best
            } else {
                best - second
            }
        })
        .collect()
}

/// Bulk-classify rows on a single core, returning per-datapoint
/// confidence margins alongside predictions — the margins-aware twin of
/// [`classify_rows_core`], with the same [`SLICED_MIN_ROWS`] kernel
/// pick.  The canary mirror and the autotune telemetry probe ride this
/// so a probe window costs the same as plain traffic.
pub fn classify_rows_margins_core(
    core: &mut Core,
    rows: &[Vec<u8>],
) -> Result<(Vec<usize>, Vec<i32>, StreamStats), CoreError> {
    if rows.len() >= SLICED_MIN_ROWS {
        return sliced_run(core, rows, true, SlicedKernel::Auto);
    }
    classify_rows_margins_core_soa(core, rows)
}

/// The 32-lane margins path: one pack pass, one reused [`BatchResult`]
/// scratch (class sums are already in it, so the margin costs only the
/// 32-lane max/runner-up scan), preds and margins appended per batch.
pub fn classify_rows_margins_core_soa(
    core: &mut Core,
    rows: &[Vec<u8>],
) -> Result<(Vec<usize>, Vec<i32>, StreamStats), CoreError> {
    if rows.is_empty() {
        return Ok((Vec::new(), Vec::new(), StreamStats::default()));
    }
    validate_rows(rows, usize::MAX)?;
    let batches = pack_stream(rows);
    let t0 = std::time::Instant::now();
    let mut preds = Vec::with_capacity(rows.len());
    let mut margins = Vec::with_capacity(rows.len());
    let mut scratch = BatchResult::default();
    let mut cycles = 0u64;
    for b in &batches {
        core.run_batch_into(b, &mut scratch)?;
        let take = (rows.len() - preds.len()).min(32);
        take_preds(&mut preds, &scratch.preds, rows.len());
        margins.extend(margins_from_sums(&scratch.class_sums, take));
        cycles += scratch.cycles.total();
    }
    let stats = StreamStats {
        batches: batches.len() as u64,
        inferences: rows.len() as u64,
        simulated_cycles: cycles,
        wall: t0.elapsed(),
    };
    Ok((preds, margins, stats))
}

/// Margins-aware bulk classify on a multi-core engine, with the same
/// [`SLICED_MIN_ROWS`] kernel pick as [`classify_rows_multicore`].
pub fn classify_rows_margins_multicore(
    mc: &mut MultiCore,
    rows: &[Vec<u8>],
) -> Result<(Vec<usize>, Vec<i32>, StreamStats), CoreError> {
    if rows.len() >= SLICED_MIN_ROWS {
        return sliced_run(mc, rows, true, SlicedKernel::Auto);
    }
    classify_rows_margins_multicore_soa(mc, rows)
}

/// The 32-lane margins path on a multi-core engine: chunked like
/// [`classify_rows_multicore_soa`] so the per-call thread spawn
/// amortizes within each [`MULTICORE_CHUNK_BATCHES`]-sized chunk while
/// retained results stay bounded by the chunk.
pub fn classify_rows_margins_multicore_soa(
    mc: &mut MultiCore,
    rows: &[Vec<u8>],
) -> Result<(Vec<usize>, Vec<i32>, StreamStats), CoreError> {
    if rows.is_empty() {
        return Ok((Vec::new(), Vec::new(), StreamStats::default()));
    }
    validate_rows(rows, usize::MAX)?;
    let batches = pack_stream(rows);
    let t0 = std::time::Instant::now();
    let mut preds = Vec::with_capacity(rows.len());
    let mut margins = Vec::with_capacity(rows.len());
    let mut n_batches = 0u64;
    let mut cycles = 0u64;
    for chunk in batches.chunks(MULTICORE_CHUNK_BATCHES) {
        let refs = as_batch_refs(chunk);
        for r in mc.run_batches(&refs)? {
            let take = (rows.len() - preds.len()).min(32);
            take_preds(&mut preds, &r.preds, rows.len());
            margins.extend(margins_from_sums(&r.class_sums, take));
            cycles += r.batch_cycles;
            n_batches += 1;
        }
    }
    let stats = StreamStats {
        batches: n_batches,
        inferences: rows.len() as u64,
        simulated_cycles: cycles,
        wall: t0.elapsed(),
    };
    Ok((preds, margins, stats))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::core::AccelConfig;
    use crate::accel::multicore::ParallelMode;
    use crate::datasets::synth::SynthSpec;
    use crate::tm::reference;
    use crate::TMShape;

    fn trained() -> (crate::TMModel, crate::datasets::synth::Dataset) {
        let shape = TMShape::synthetic(12, 4, 8);
        let data = SynthSpec::new(12, 4, 200).noise(0.05).seed(17).generate();
        let model = crate::trainer::train_model(&shape, &data, 4, 2);
        (model, data)
    }

    #[test]
    fn pack_stream_chunks_rows() {
        let rows: Vec<Vec<u8>> = (0..70).map(|i| vec![(i & 1) as u8; 12]).collect();
        let batches = pack_stream(&rows);
        assert_eq!(batches.len(), 3); // 32 + 32 + 6
        assert_eq!(batches[0].len(), 12);
        assert_eq!(batches[0], isa::pack_features(&rows[..32]));
    }

    #[test]
    fn core_stream_matches_per_row_reference() {
        let (model, data) = trained();
        let mut core = Core::new(AccelConfig::base());
        core.program_model(&model).unwrap();
        let (preds, stats) = classify_rows_core(&mut core, &data.xs).unwrap();
        assert_eq!(preds.len(), data.len());
        assert_eq!(stats.inferences, data.len() as u64);
        assert_eq!(stats.batches, data.xs.chunks(32).count() as u64);
        assert!(stats.simulated_cycles > 0);
        for (x, &p) in data.xs.iter().zip(&preds) {
            let lits = reference::literals_from_features(x);
            assert_eq!(p, reference::predict_dense(&model, &lits));
        }
    }

    #[test]
    fn multicore_stream_matches_core_stream() {
        let (model, data) = trained();
        let mut core = Core::new(AccelConfig::base());
        core.program_model(&model).unwrap();
        let mut mc = MultiCore::five_core().with_parallel(ParallelMode::Threads);
        mc.program_model(&model).unwrap();
        let (a, _) = classify_rows_core(&mut core, &data.xs).unwrap();
        let (b, stats) = classify_rows_multicore(&mut mc, &data.xs).unwrap();
        assert_eq!(a, b);
        assert_eq!(stats.inferences, data.len() as u64);
    }

    #[test]
    fn validate_rows_rejects_malformed_batches() {
        assert!(matches!(
            validate_rows(&[], 32),
            Err(CoreError::BadBatch { rows: 0, .. })
        ));
        let thirty_three: Vec<Vec<u8>> = vec![vec![0u8; 4]; 33];
        assert!(matches!(
            validate_rows(&thirty_three, 32),
            Err(CoreError::BadBatch { rows: 33, .. })
        ));
        // The bulk paths take any row count…
        assert!(validate_rows(&thirty_three, usize::MAX).is_ok());
        // …but never ragged widths.
        let ragged = vec![vec![0u8; 4], vec![0u8; 5]];
        assert!(matches!(
            validate_rows(&ragged, 32),
            Err(CoreError::BadBatch { rows: 2, .. })
        ));
        assert!(validate_rows(&[vec![0u8; 4], vec![1u8; 4]], 32).is_ok());
    }

    #[test]
    fn classify_rows_rejects_ragged_and_accepts_empty() {
        let (model, _) = trained();
        let mut core = Core::new(AccelConfig::base());
        core.program_model(&model).unwrap();
        let ragged = vec![vec![0u8; 12], vec![0u8; 7]];
        assert!(matches!(
            classify_rows_core(&mut core, &ragged),
            Err(CoreError::BadBatch { .. })
        ));
        let (preds, stats) = classify_rows_core(&mut core, &[]).unwrap();
        assert!(preds.is_empty());
        assert_eq!(stats.batches, 0);

        let mut mc = MultiCore::five_core();
        mc.program_model(&model).unwrap();
        assert!(matches!(
            classify_rows_multicore(&mut mc, &ragged),
            Err(CoreError::BadBatch { .. })
        ));
        let (preds, _) = classify_rows_multicore(&mut mc, &[]).unwrap();
        assert!(preds.is_empty());
    }

    #[test]
    fn margins_bulk_path_matches_per_batch_reference() {
        let (model, data) = trained();
        let mut core = Core::new(AccelConfig::base());
        core.program_model(&model).unwrap();
        let (preds, margins, stats) = classify_rows_margins_core(&mut core, &data.xs).unwrap();
        assert_eq!(preds.len(), data.len());
        assert_eq!(margins.len(), data.len());
        assert_eq!(stats.inferences, data.len() as u64);
        // Margins equal the dense reference's top1 - top2 gap.
        for ((x, &p), &m) in data.xs.iter().zip(&preds).zip(&margins) {
            let lits = reference::literals_from_features(x);
            let mut sums = reference::class_sums_dense(&model, &lits);
            assert_eq!(p, reference::predict_dense(&model, &lits));
            sums.sort_unstable_by(|a, b| b.cmp(a));
            assert_eq!(m, sums[0] - sums[1]);
        }
        // Multi-core path agrees byte for byte (preds AND margins).
        let mut mc = MultiCore::five_core().with_parallel(ParallelMode::Threads);
        mc.program_model(&model).unwrap();
        let (p2, m2, _) = classify_rows_margins_multicore(&mut mc, &data.xs).unwrap();
        assert_eq!(preds, p2);
        assert_eq!(margins, m2);
    }

    #[test]
    fn margins_bulk_path_rejects_ragged_and_accepts_empty() {
        let (model, _) = trained();
        let mut core = Core::new(AccelConfig::base());
        core.program_model(&model).unwrap();
        let ragged = vec![vec![0u8; 12], vec![0u8; 7]];
        assert!(matches!(
            classify_rows_margins_core(&mut core, &ragged),
            Err(CoreError::BadBatch { .. })
        ));
        let (preds, margins, stats) = classify_rows_margins_core(&mut core, &[]).unwrap();
        assert!(preds.is_empty() && margins.is_empty());
        assert_eq!(stats.batches, 0);
    }

    #[test]
    fn sliced_and_soa_bulk_paths_are_byte_identical() {
        // Above SLICED_MIN_ROWS the auto paths ride the 64-lane kernel;
        // preds, margins AND StreamStats counters must match the pinned
        // 32-lane path exactly.
        let (model, data) = trained();
        let rows: Vec<Vec<u8>> = (0..SLICED_MIN_ROWS + 37)
            .map(|i| data.xs[i % data.len()].clone())
            .collect();

        let mut core = Core::new(AccelConfig::base());
        core.program_model(&model).unwrap();
        let (soa_preds, soa_stats) = classify_rows_core_soa(&mut core, &rows).unwrap();
        let (auto_preds, auto_stats) = classify_rows_core(&mut core, &rows).unwrap();
        assert_eq!(auto_preds, soa_preds);
        assert_eq!(auto_stats.batches, soa_stats.batches);
        assert_eq!(auto_stats.inferences, soa_stats.inferences);
        assert_eq!(auto_stats.simulated_cycles, soa_stats.simulated_cycles);

        let (m_soa_preds, m_soa_margins, _) =
            classify_rows_margins_core_soa(&mut core, &rows).unwrap();
        let (m_preds, m_margins, m_stats) =
            classify_rows_margins_core(&mut core, &rows).unwrap();
        assert_eq!(m_preds, m_soa_preds);
        assert_eq!(m_margins, m_soa_margins);
        assert_eq!(m_stats.simulated_cycles, soa_stats.simulated_cycles);

        // Multi-core: auto (sliced) vs the pinned 32-lane chunked path.
        let mut mc = MultiCore::five_core().with_parallel(ParallelMode::Threads);
        mc.program_model(&model).unwrap();
        let (mc_soa_preds, mc_soa_stats) = classify_rows_multicore_soa(&mut mc, &rows).unwrap();
        let (mc_preds, mc_stats) = classify_rows_multicore(&mut mc, &rows).unwrap();
        assert_eq!(mc_preds, mc_soa_preds);
        assert_eq!(mc_preds, soa_preds);
        assert_eq!(mc_stats.batches, mc_soa_stats.batches);
        assert_eq!(mc_stats.simulated_cycles, mc_soa_stats.simulated_cycles);
        let (mm_preds, mm_margins, _) = classify_rows_margins_multicore(&mut mc, &rows).unwrap();
        assert_eq!(mm_preds, m_preds);
        assert_eq!(mm_margins, m_margins);
    }

    #[test]
    fn sliced_bulk_path_rejects_malformed_requests() {
        let (model, _) = trained();
        let mut core = Core::new(AccelConfig::base());
        core.program_model(&model).unwrap();
        // A ragged stream above the threshold still dies in
        // validate_rows, never in the transpose asserts.
        let mut ragged: Vec<Vec<u8>> = vec![vec![0u8; 12]; SLICED_MIN_ROWS + 1];
        ragged[100] = vec![0u8; 5];
        assert!(matches!(
            classify_rows_core(&mut core, &ragged),
            Err(CoreError::BadBatch { .. })
        ));
        assert!(matches!(
            classify_rows_margins_core(&mut core, &ragged),
            Err(CoreError::BadBatch { .. })
        ));
        let mut mc = MultiCore::five_core();
        mc.program_model(&model).unwrap();
        assert!(matches!(
            classify_rows_multicore(&mut mc, &ragged),
            Err(CoreError::BadBatch { .. })
        ));
    }

    #[test]
    fn ragged_tail_is_preserved() {
        let (model, data) = trained();
        let mut core = Core::new(AccelConfig::base());
        core.program_model(&model).unwrap();
        let rows = &data.xs[..37];
        let (preds, stats) = classify_rows_core(&mut core, rows).unwrap();
        assert_eq!(preds.len(), 37);
        assert_eq!(stats.batches, 2);
    }
}
