//! On-chip memory models: instruction memory, feature memory, BRAM
//! accounting (the Fig 6 customization axis).
//!
//! Depths are deploy-time parameters; programming past the configured
//! depth is a capacity error — exactly the runtime-tunability headroom
//! trade-off the paper's Fig 6 explores (deeper memories = more
//! tunability later, at LUT/FF/power/f_max cost).

use crate::isa::Instr;

/// Bits per Xilinx BRAM18 block.
pub const BRAM18_BITS: usize = 18 * 1024;

/// Capacity errors surface to the programming stream handler.
#[derive(Debug, PartialEq, Eq, thiserror::Error)]
pub enum MemError {
    #[error("instruction memory full: model needs {need} entries, depth is {depth}")]
    InstrOverflow { need: usize, depth: usize },
    #[error("feature memory full: {need} words needed, depth is {depth}")]
    FeatureOverflow { need: usize, depth: usize },
}

/// Instruction memory: `depth` 16-bit words.
#[derive(Debug, Clone)]
pub struct InstrMemory {
    pub depth: usize,
    data: Vec<Instr>,
}

impl InstrMemory {
    pub fn new(depth: usize) -> Self {
        InstrMemory { depth, data: Vec::new() }
    }

    /// Load a full model (the paper reprograms whole models atomically).
    pub fn program(&mut self, instrs: &[Instr]) -> Result<(), MemError> {
        if instrs.len() > self.depth {
            return Err(MemError::InstrOverflow { need: instrs.len(), depth: self.depth });
        }
        self.data = instrs.to_vec();
        Ok(())
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    #[inline]
    pub fn fetch(&self, addr: usize) -> Instr {
        self.data[addr]
    }

    pub fn contents(&self) -> &[Instr] {
        &self.data
    }

    /// BRAM18 blocks this depth requires (16-bit entries).
    pub fn brams(&self) -> usize {
        (self.depth * 16).div_ceil(BRAM18_BITS)
    }
}

/// Feature memory: `depth` bit-sliced u32 words (one word = one Boolean
/// feature across 32 batched datapoints, Fig 4.5).
#[derive(Debug, Clone)]
pub struct FeatureMemory {
    pub depth: usize,
    data: Vec<u32>,
}

impl FeatureMemory {
    pub fn new(depth: usize) -> Self {
        FeatureMemory { depth, data: Vec::new() }
    }

    /// Load one batch worth of feature words.  Reuses the backing buffer
    /// (the BRAM is fixed storage; the host model should not allocate
    /// per batch either — §Perf in EXPERIMENTS.md).
    pub fn load(&mut self, words: &[u32]) -> Result<(), MemError> {
        if words.len() > self.depth {
            return Err(MemError::FeatureOverflow { need: words.len(), depth: self.depth });
        }
        self.data.clear();
        self.data.extend_from_slice(words);
        Ok(())
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Raw bit-sliced contents — the SoA walk reads this directly and
    /// applies the L bit as a predecoded XOR mask instead of the
    /// per-read branch in [`Self::literal_word`].
    #[inline]
    pub fn words(&self) -> &[u32] {
        &self.data
    }

    /// Literal-select stage read (Fig 4.5): feature word + L-bit invert.
    #[inline]
    pub fn literal_word(&self, feature: usize, complement: bool) -> u32 {
        let w = self.data[feature];
        if complement {
            !w
        } else {
            w
        }
    }

    /// BRAM18 blocks this depth requires (32-bit entries).
    pub fn brams(&self) -> usize {
        (self.depth * 32).div_ceil(BRAM18_BITS)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn program_within_depth() {
        let mut m = InstrMemory::new(4);
        let instrs: Vec<Instr> = (0..3u16).map(Instr).collect();
        m.program(&instrs).unwrap();
        assert_eq!(m.len(), 3);
        assert_eq!(m.fetch(2), Instr(2));
    }

    #[test]
    fn program_overflow_rejected() {
        let mut m = InstrMemory::new(2);
        let instrs: Vec<Instr> = (0..3u16).map(Instr).collect();
        assert_eq!(
            m.program(&instrs),
            Err(MemError::InstrOverflow { need: 3, depth: 2 })
        );
    }

    #[test]
    fn reprogram_replaces_whole_model() {
        let mut m = InstrMemory::new(8);
        m.program(&[Instr(1), Instr(2)]).unwrap();
        m.program(&[Instr(9)]).unwrap();
        assert_eq!(m.len(), 1);
        assert_eq!(m.fetch(0), Instr(9));
    }

    #[test]
    fn feature_literal_select() {
        let mut f = FeatureMemory::new(4);
        f.load(&[0b1010, 0xFFFF_FFFF]).unwrap();
        assert_eq!(f.literal_word(0, false), 0b1010);
        assert_eq!(f.literal_word(0, true), !0b1010u32);
        assert_eq!(f.literal_word(1, true), 0);
    }

    #[test]
    fn feature_reload_replaces_contents() {
        let mut f = FeatureMemory::new(4);
        f.load(&[1, 2, 3]).unwrap();
        f.load(&[9]).unwrap();
        assert_eq!(f.words(), &[9]);
        assert_eq!(f.len(), 1);
    }

    #[test]
    fn feature_overflow_rejected() {
        let mut f = FeatureMemory::new(1);
        assert_eq!(
            f.load(&[1, 2]),
            Err(MemError::FeatureOverflow { need: 2, depth: 1 })
        );
    }

    #[test]
    fn bram_accounting() {
        // 8192 x 16b = 128 Kib -> ceil(131072/18432) = 8 BRAM18.
        assert_eq!(InstrMemory::new(8192).brams(), 8);
        // 2048 x 32b = 64 Kib -> 4 BRAM18.
        assert_eq!(FeatureMemory::new(2048).brams(), 4);
        // Tiny memories still take one block.
        assert_eq!(InstrMemory::new(16).brams(), 1);
    }
}
