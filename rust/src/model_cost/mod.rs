//! Physical-cost models: the quantities the simulator cannot produce
//! (LUTs/FFs/BRAMs/f_max and power), calibrated against the paper's own
//! published numbers.
//!
//! * [`resources`] — Table 1 anchors + the Fig 6 memory-depth scaling.
//! * [`energy`] — per-configuration power (recovered from the paper's
//!   energy/latency pairs) and the E = P x t arithmetic of Fig 9/Table 2.

pub mod energy;
pub mod resources;

pub use energy::{EnergyModel, PowerBudget};
pub use resources::{
    estimate, estimate_multicore, fitted_config, provisioned_config, ResourceBudget,
    ResourceEstimate,
};
