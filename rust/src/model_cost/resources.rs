//! LUT/FF/BRAM/f_max model (Table 1 anchors, Fig 6 scaling).
//!
//! The three deployed configurations are *anchored* to the paper's
//! Table 1 measurements; deviations from the anchor's memory depths
//! (the Fig 6 customization sweep) apply marginal costs:
//!
//! * +`LUT_PER_ADDR_BIT` LUTs and +`FF_PER_ADDR_BIT` FFs per extra
//!   address bit (wider decoders/counters),
//! * BRAM count from the actual memory geometry
//!   ([`crate::accel::memory`]) plus a per-configuration interconnect
//!   constant,
//! * f_max derates `FREQ_DERATE_PER_BIT` per extra address bit (longer
//!   BRAM cascade paths) — the Fig 6 "lower frequency" trend.

use crate::accel::core::AccelConfig;
use crate::accel::memory::{FeatureMemory, InstrMemory};

/// Marginal LUTs per extra memory address bit.
pub const LUT_PER_ADDR_BIT: f64 = 55.0;
/// Marginal FFs per extra memory address bit.
pub const FF_PER_ADDR_BIT: f64 = 90.0;
/// Fractional f_max derate per extra address bit.
pub const FREQ_DERATE_PER_BIT: f64 = 0.03;

/// One row of Table 1.
#[derive(Debug, Clone, PartialEq)]
pub struct ResourceEstimate {
    pub name: String,
    pub chip: &'static str,
    pub luts: u32,
    pub ffs: u32,
    pub brams: u32,
    pub freq_mhz: f64,
}

/// Anchor points: the paper's Table 1 rows.
struct Anchor {
    chip: &'static str,
    luts: f64,
    ffs: f64,
    brams_fixed: u32, // interconnect/FIFO blocks beyond the two memories
    freq_mhz: f64,
    instr_depth: usize,
    feature_depth: usize,
}

fn anchor_for(cfg_name: &str) -> Anchor {
    match cfg_name {
        // Base (B): A7035, 1340 LUT / 2228 FF / 14 BRAM / 200 MHz.
        "base" => Anchor {
            chip: "A7035",
            luts: 1340.0,
            ffs: 2228.0,
            brams_fixed: 2,
            freq_mhz: 200.0,
            instr_depth: 8192,
            feature_depth: 2048,
        },
        // Single Core (S): Z7020, 3480 / 5154 / 43 / 100.
        "single_core" => Anchor {
            chip: "Z7020",
            luts: 3480.0,
            ffs: 5154.0,
            brams_fixed: 3,
            freq_mhz: 100.0,
            instr_depth: 28672,
            feature_depth: 8192,
        },
        // Per-core anchor inside Multi-Core (M); the multicore estimate
        // below adds the AXIS splitter + interconnect.
        "multicore" => Anchor {
            chip: "Z7020",
            luts: 1340.0,
            ffs: 1665.0,
            brams_fixed: 0,
            freq_mhz: 100.0,
            instr_depth: 4096,
            feature_depth: 2048,
        },
        other => panic!("no resource anchor for config {other}"),
    }
}

fn log2(v: usize) -> f64 {
    (v.max(1) as f64).log2()
}

/// Estimate one core's resources at its configured memory depths.
pub fn estimate(cfg: &AccelConfig) -> ResourceEstimate {
    let a = anchor_for(cfg.name);
    let delta_bits = (log2(cfg.instr_depth) - log2(a.instr_depth))
        + (log2(cfg.feature_depth) - log2(a.feature_depth));
    let brams = InstrMemory::new(cfg.instr_depth).brams()
        + FeatureMemory::new(cfg.feature_depth).brams()
        + a.brams_fixed as usize;
    ResourceEstimate {
        name: cfg.name.to_string(),
        chip: a.chip,
        luts: (a.luts + LUT_PER_ADDR_BIT * delta_bits).round().max(0.0) as u32,
        ffs: (a.ffs + FF_PER_ADDR_BIT * delta_bits).round().max(0.0) as u32,
        brams: brams as u32,
        freq_mhz: a.freq_mhz * (1.0 - FREQ_DERATE_PER_BIT * delta_bits.max(0.0)),
    }
}

/// The multi-core build: n cores + AXIS splitter/interconnect
/// (anchored to Table 1's M row: 9814 / 10909 / 43 at 5 cores).
pub fn estimate_multicore(per_core: &AccelConfig, n: usize) -> ResourceEstimate {
    let core = estimate(per_core);
    // Anchored so 5 x multicore_core + overhead = Table 1's M row.
    let overhead_luts = 9814.0 - 5.0 * 1340.0; // AXIS splitter + merge
    let overhead_ffs = 10909.0 - 5.0 * 1665.0;
    let overhead_brams = 3u32;
    ResourceEstimate {
        name: format!("multicore_x{n}"),
        chip: core.chip,
        luts: (core.luts as f64 * n as f64 + overhead_luts).round() as u32,
        ffs: (core.ffs as f64 * n as f64 + overhead_ffs).round() as u32,
        brams: core.brams * n as u32 + overhead_brams,
        freq_mhz: core.freq_mhz,
    }
}

/// The Fig 6 sweep: resources/f_max of the base build across feature- and
/// instruction-memory depths.
pub fn memory_depth_sweep(depths: &[(usize, usize)]) -> Vec<(usize, usize, ResourceEstimate)> {
    depths
        .iter()
        .map(|&(di, df)| {
            let cfg = AccelConfig::base().with_depths(di, df);
            (di, df, estimate(&cfg))
        })
        .collect()
}

/// Minimum memory depths a workload needs (the Fig 6 vertical lines):
/// instruction entries for the compressed model, feature words for one
/// batch.
pub fn min_depths(model: &crate::tm::model::TMModel) -> (usize, usize) {
    (crate::isa::instruction_count(model), model.shape.features)
}

/// Base-build configuration with memory depths fitted to `model` (the
/// Fig 6 deploy-time customization): power-of-two depths just large
/// enough for the compressed stream and one feature batch.  This is the
/// deployment the autotuner costs a candidate model at when checking it
/// against a [`ResourceBudget`].
pub fn fitted_config(model: &crate::tm::model::TMModel) -> AccelConfig {
    let (di, df) = min_depths(model);
    AccelConfig::base().with_depths(
        di.next_power_of_two().max(1024),
        df.next_power_of_two().max(512),
    )
}

/// Base-build configuration provisioned for *runtime retuning*:
/// power-of-two depths covering `model` with the stock base floors
/// (8192 instruction entries / 2048 feature words) and an
/// instruction-side `headroom` multiplier (>= 1), so retrained
/// candidates carrying more includes than the first model still swap
/// in without resynthesis — the paper's "BRAMs … over-provisioned for
/// more tunability later".  This is the one place the CLI, benches and
/// examples size an autotuned pool's memories.
pub fn provisioned_config(model: &crate::tm::model::TMModel, headroom: usize) -> AccelConfig {
    let (di, df) = min_depths(model);
    AccelConfig::base().with_depths(
        headroom.max(1) * di.next_power_of_two().max(8192),
        df.next_power_of_two().max(2048),
    )
}

/// Bytes of the ETHEREAL-style compressed include-list form of `model`
/// ([`crate::isa::CompressedProgram`]): one u16 entry
/// (`feature << 1 | complement`) per include, i.e. per Include
/// instruction of the programming stream.  This is the BRAM footprint a
/// compressed deployment actually stores — include lists, not dense
/// literal planes — and the byte axis [`ResourceBudget::admits_model`]
/// trades accuracy against.
pub fn compressed_model_bytes(model: &crate::tm::model::TMModel) -> u32 {
    (crate::isa::instruction_count(model) * std::mem::size_of::<u16>()) as u32
}

/// A resource frontier for runtime model selection: the autotuner only
/// installs models whose fitted deployment ([`fitted_config`] →
/// [`estimate`] + [`crate::model_cost::energy::EnergyModel`]) stays
/// inside it.  `None` leaves an axis unconstrained.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ResourceBudget {
    pub max_luts: Option<u32>,
    pub max_brams: Option<u32>,
    /// Average-power ceiling in watts.
    pub max_watts: Option<f64>,
    /// Ceiling on the COMPRESSED model size in bytes
    /// ([`compressed_model_bytes`]) — the include-list storage a sparse
    /// deployment keeps resident, independent of the synthesized memory
    /// depths the LUT/BRAM axes already price.
    pub max_model_bytes: Option<u32>,
}

impl ResourceBudget {
    /// No constraints on any axis.
    pub fn unlimited() -> Self {
        Self::default()
    }

    pub fn with_luts(mut self, v: u32) -> Self {
        self.max_luts = Some(v);
        self
    }

    pub fn with_brams(mut self, v: u32) -> Self {
        self.max_brams = Some(v);
        self
    }

    pub fn with_watts(mut self, v: f64) -> Self {
        self.max_watts = Some(v);
        self
    }

    pub fn with_model_bytes(mut self, v: u32) -> Self {
        self.max_model_bytes = Some(v);
        self
    }

    /// True when the estimated deployment fits every configured axis.
    pub fn admits(&self, est: &ResourceEstimate, watts: f64) -> bool {
        self.max_luts.map(|m| est.luts <= m).unwrap_or(true)
            && self.max_brams.map(|m| est.brams <= m).unwrap_or(true)
            && self.max_watts.map(|m| watts <= m).unwrap_or(true)
    }

    /// [`Self::admits`] plus the compressed-model-byte axis: the fitted
    /// deployment must fit AND the candidate's include-list bytes
    /// ([`compressed_model_bytes`]) must stay under `max_model_bytes`.
    pub fn admits_model(&self, est: &ResourceEstimate, watts: f64, model_bytes: u32) -> bool {
        self.admits(est, watts)
            && self.max_model_bytes.map(|m| model_bytes <= m).unwrap_or(true)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn base_anchor_reproduces_table1() {
        let r = estimate(&AccelConfig::base());
        assert_eq!((r.luts, r.ffs, r.brams), (1340, 2228, 14));
        assert_eq!(r.freq_mhz, 200.0);
        assert_eq!(r.chip, "A7035");
    }

    #[test]
    fn single_core_anchor_reproduces_table1() {
        let cfg = AccelConfig::single_core();
        let r = estimate(&cfg);
        assert_eq!((r.luts, r.ffs), (3480, 5154));
        assert_eq!(r.freq_mhz, 100.0);
    }

    #[test]
    fn five_core_anchor_reproduces_table1() {
        let r = estimate_multicore(&AccelConfig::multicore_core(), 5);
        assert_eq!((r.luts, r.ffs), (9814, 10909));
    }

    #[test]
    fn deeper_memory_costs_resources_and_frequency() {
        let base = estimate(&AccelConfig::base());
        let deep = estimate(&AccelConfig::base().with_depths(8192 * 4, 2048 * 4));
        assert!(deep.luts > base.luts);
        assert!(deep.ffs > base.ffs);
        assert!(deep.brams > base.brams);
        assert!(deep.freq_mhz < base.freq_mhz);
    }

    #[test]
    fn shallower_memory_saves_luts() {
        let base = estimate(&AccelConfig::base());
        let shallow = estimate(&AccelConfig::base().with_depths(1024, 512));
        assert!(shallow.luts < base.luts);
        assert!(shallow.brams < base.brams);
    }

    #[test]
    fn sweep_is_monotone_in_depth() {
        let sweep = memory_depth_sweep(&[(1024, 512), (4096, 1024), (16384, 4096)]);
        for w in sweep.windows(2) {
            assert!(w[1].2.luts >= w[0].2.luts);
            assert!(w[1].2.freq_mhz <= w[0].2.freq_mhz);
        }
    }

    #[test]
    fn budget_admits_and_rejects_per_axis() {
        let est = estimate(&AccelConfig::base()); // 1340 LUT / 14 BRAM
        let watts = 0.351;
        assert!(ResourceBudget::unlimited().admits(&est, watts));
        assert!(ResourceBudget::unlimited().with_luts(1340).admits(&est, watts));
        assert!(!ResourceBudget::unlimited().with_luts(1339).admits(&est, watts));
        assert!(!ResourceBudget::unlimited().with_brams(13).admits(&est, watts));
        assert!(!ResourceBudget::unlimited().with_watts(0.35).admits(&est, watts));
        assert!(ResourceBudget::unlimited()
            .with_luts(2000)
            .with_brams(20)
            .with_watts(0.4)
            .admits(&est, watts));
    }

    #[test]
    fn fitted_config_covers_model_and_stays_small() {
        let mut m = crate::tm::model::TMModel::empty(crate::TMShape::synthetic(8, 2, 4));
        m.set_include(0, 0, 0, true);
        m.set_include(1, 1, 3, true);
        let cfg = fitted_config(&m);
        assert_eq!(cfg.name, "base");
        assert_eq!((cfg.instr_depth, cfg.feature_depth), (1024, 512));
        // A small fitted deployment costs fewer LUTs than the stock base.
        assert!(estimate(&cfg).luts < estimate(&AccelConfig::base()).luts);
    }

    #[test]
    fn provisioned_config_applies_floors_and_headroom() {
        let mut m = crate::tm::model::TMModel::empty(crate::TMShape::synthetic(8, 2, 4));
        m.set_include(0, 0, 0, true);
        let p1 = provisioned_config(&m, 1);
        // Stock base floors for a tiny model.
        assert_eq!((p1.instr_depth, p1.feature_depth), (8192, 2048));
        let p2 = provisioned_config(&m, 2);
        assert_eq!(p2.instr_depth, 2 * 8192);
        assert_eq!(p2.feature_depth, 2048); // headroom is instruction-side only
        // headroom 0 is clamped to 1.
        assert_eq!(provisioned_config(&m, 0).instr_depth, 8192);
        assert_eq!(p1.name, "base");
    }

    #[test]
    fn model_byte_axis_gates_admission() {
        let est = estimate(&AccelConfig::base());
        let watts = 0.3;
        let mut m = crate::tm::model::TMModel::empty(crate::TMShape::synthetic(8, 2, 4));
        m.set_include(0, 0, 0, true);
        m.set_include(1, 1, 3, true);
        // Two includes → 2 instructions → 4 bytes of u16 include entries.
        let bytes = compressed_model_bytes(&m);
        assert_eq!(bytes, 4);
        assert!(ResourceBudget::unlimited().admits_model(&est, watts, bytes));
        assert!(ResourceBudget::unlimited()
            .with_model_bytes(4)
            .admits_model(&est, watts, bytes));
        assert!(!ResourceBudget::unlimited()
            .with_model_bytes(3)
            .admits_model(&est, watts, bytes));
        // The byte axis composes with the existing axes.
        assert!(!ResourceBudget::unlimited()
            .with_luts(10)
            .with_model_bytes(1 << 20)
            .admits_model(&est, watts, bytes));
        // Plain `admits` is unchanged by the new field.
        assert!(ResourceBudget::unlimited().with_model_bytes(1).admits(&est, watts));
    }

    #[test]
    fn min_depths_track_model_size() {
        let mut m = crate::tm::model::TMModel::empty(crate::TMShape::synthetic(8, 2, 4));
        m.set_include(0, 0, 0, true);
        m.set_include(1, 1, 3, true);
        let (di, df) = min_depths(&m);
        assert_eq!(di, 2);
        assert_eq!(df, 8);
    }
}
