//! Power/energy model — the E = P x t arithmetic behind Fig 9 & Table 2.
//!
//! The paper's energy numbers divide by their latencies to a constant
//! per-configuration power (verified across Table 2 rows):
//!
//! * Base:        2.610uJ / 7.44us  = 13.268uJ / 37.80us = **0.351 W**
//! * Single Core: 21.279uJ / 14.87us                     = **1.431 W**
//! * 5-Core:      11.429uJ / 7.64us                      = **1.496 W**
//! * ESP32:       1451.1uJ / 18528us (HAR, Gesture, ...) = **78.3 mW**
//!
//! Those recovered constants are the calibration anchors here.  The
//! depth-dependent term models the Fig 6 "more power at deeper
//! memories" trend (active BRAM leakage + wider address toggling).

use crate::accel::core::AccelConfig;
use crate::accel::memory::{FeatureMemory, InstrMemory};

/// Calibrated average power per configuration, in watts.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PowerBudget {
    pub watts: f64,
}

/// Recovered from the paper's E/L pairs (see module docs).
pub const P_BASE_W: f64 = 0.351;
pub const P_SINGLE_W: f64 = 1.431;
pub const P_MULTI_W: f64 = 1.496;
/// ESP32 software baseline (Table 2).
pub const P_ESP32_W: f64 = 0.0783;
/// STM32F746 Discovery running REDRESS-style inference ([15], "RDRS" in
/// Fig 9).  Fig 9's raw values are not printed in the text; this is the
/// board's typical active power at 216 MHz, documented as an assumption
/// in EXPERIMENTS.md.
pub const P_STM32_W: f64 = 0.392;
/// MATADOR accelerators on Z7020 @ 50 MHz (assumption, see
/// EXPERIMENTS.md; chosen so the Fig 9 energy ordering holds).
pub const P_MATADOR_W: f64 = 0.55;

/// Additional watts per active BRAM18 beyond the anchor count (Fig 6
/// power trend).
pub const P_PER_EXTRA_BRAM_W: f64 = 0.004;

/// Energy model for one accelerator configuration.
#[derive(Debug, Clone)]
pub struct EnergyModel {
    pub name: String,
    pub watts: f64,
    pub freq_mhz: f64,
}

impl EnergyModel {
    /// Model for a (possibly depth-customized) core configuration.
    pub fn for_config(cfg: &AccelConfig) -> Self {
        // Anchor BRAM counts are the two memories only (the fixed
        // interconnect blocks don't scale with depth).
        let (anchor_w, anchor_brams) = match cfg.name {
            "base" => (P_BASE_W, 12.0),        // 8 instr + 4 feature
            "single_core" => (P_SINGLE_W, 40.0), // 25 + 15
            "multicore" => (P_MULTI_W / 5.0, 8.0), // 4 + 4 per core
            other => panic!("no power anchor for config {other}"),
        };
        let brams = (InstrMemory::new(cfg.instr_depth).brams()
            + FeatureMemory::new(cfg.feature_depth).brams()) as f64;
        let watts = anchor_w + P_PER_EXTRA_BRAM_W * (brams - anchor_brams).max(-anchor_brams * 0.5);
        EnergyModel { name: cfg.name.to_string(), watts, freq_mhz: cfg.freq_mhz }
    }

    /// Whole multi-core build (n cores + interconnect).
    pub fn for_multicore(per_core: &AccelConfig, n: usize) -> Self {
        let one = Self::for_config(per_core);
        EnergyModel {
            name: format!("multicore_x{n}"),
            // Interconnect/AXIS overhead is the residual of the 5-core
            // anchor.
            watts: one.watts * n as f64 + (P_MULTI_W - 5.0 * (P_MULTI_W / 5.0)),
            freq_mhz: per_core.freq_mhz,
        }
    }

    /// Energy in microjoules for a latency in microseconds.
    pub fn energy_uj(&self, latency_us: f64) -> f64 {
        self.watts * latency_us
    }

    /// Latency in us for a cycle count at this model's clock.
    pub fn latency_us(&self, cycles: u64) -> f64 {
        cycles as f64 / self.freq_mhz
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn base_power_matches_paper_recovery() {
        let m = EnergyModel::for_config(&AccelConfig::base());
        assert!((m.watts - P_BASE_W).abs() < 1e-9);
    }

    #[test]
    fn paper_energy_rows_reproduce() {
        // Table 2, EMG row: Base 7.44us batch -> 2.610uJ.
        let m = EnergyModel::for_config(&AccelConfig::base());
        let e = m.energy_uj(7.44);
        assert!((e - 2.610).abs() < 0.01, "got {e}");
        // HAR row: 37.80us -> 13.268uJ.
        let e = m.energy_uj(37.80);
        assert!((e - 13.268).abs() < 0.02, "got {e}");
    }

    #[test]
    fn single_core_power() {
        let m = EnergyModel::for_config(&AccelConfig::single_core());
        // Anchor depths differ from the single_core() preset by design
        // head-room; allow the small BRAM-term delta.
        assert!((m.watts - P_SINGLE_W).abs() < 0.05, "{}", m.watts);
        // Table 2 EMG: 14.87us -> 21.279uJ.
        let e = P_SINGLE_W * 14.87;
        assert!((e - 21.279).abs() < 0.03, "got {e}");
    }

    #[test]
    fn five_core_power() {
        let m = EnergyModel::for_multicore(&AccelConfig::multicore_core(), 5);
        assert!((m.watts - P_MULTI_W).abs() < 0.08, "{}", m.watts);
    }

    #[test]
    fn deeper_memory_draws_more_power() {
        let base = EnergyModel::for_config(&AccelConfig::base());
        let deep = EnergyModel::for_config(&AccelConfig::base().with_depths(32768, 8192));
        assert!(deep.watts > base.watts);
    }

    #[test]
    fn latency_us_uses_clock() {
        let m = EnergyModel::for_config(&AccelConfig::base());
        assert!((m.latency_us(200) - 1.0).abs() < 1e-12); // 200 cycles @ 200MHz
    }
}
