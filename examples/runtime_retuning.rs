//! END-TO-END DRIVER (EXPERIMENTS.md §End-to-end): the paper's Fig 8
//! deployment, with every layer of the stack composing:
//!
//!   L1 Pallas clause kernels -> L2 JAX train/infer graphs -> AOT HLO
//!   artifacts -> L3 rust: PJRT training node + cycle-accurate
//!   accelerator + recalibration loop.
//!
//! Scenario (EMG gesture recognition, the paper's user-personalization
//! case):
//!  1. train on clean data via the **PJRT train-step artifact** (Python
//!     is not running — the JAX graph was AOT-compiled at build time);
//!  2. deploy to the simulated Base accelerator; verify the accelerator,
//!     the dense reference and the **PJRT inference artifact** agree;
//!  3. inject sensor drift; watch accuracy collapse;
//!  4. the training node retrains on the drifted window and reprograms
//!     the accelerator over its instruction stream — *no resynthesis*;
//!  5. report the accuracy trace and the programming cost in cycles.
//!
//! ```sh
//! make artifacts && cargo run --release --example runtime_retuning
//! ```

use rttm::config::Manifest;
use rttm::coordinator::{Engine, InferenceService, RecalibrationLoop, TrainingNode};
use rttm::datasets::workloads::workload;
use rttm::isa;
use rttm::runtime::Runtime;
use rttm::tm::reference;

fn main() -> anyhow::Result<()> {
    let w = workload("emg")?;
    let manifest = Manifest::load_default()?;
    let rt = Runtime::cpu()?;
    println!("PJRT platform: {}", rt.platform());

    // --- 1. Train via the AOT JAX artifact. ------------------------------
    let train_exe = rt.load_train(&manifest, "emg")?;
    let infer_exe = rt.load_infer(&manifest, "emg")?;
    let clean = w.dataset(1024, 7);
    let (train, probe) = clean.split(0.75);

    let t0 = std::time::Instant::now();
    let node = TrainingNode::pjrt(w.shape.clone(), train_exe);
    let model = node.retrain(&train)?;
    println!(
        "[train] PJRT train-step artifact: {:.2}s, {} includes ({:.2}% sparse)",
        t0.elapsed().as_secs_f64(),
        model.include_count(),
        100.0 * model.sparsity()
    );

    // --- 2. Deploy + three-way agreement check. --------------------------
    let mut svc = InferenceService::new(Engine::base());
    svc.reprogram(&model)?;

    let rows: Vec<Vec<u8>> = probe.xs[..32].to_vec();
    let accel_preds = svc.infer(&rows)?;
    let lit_rows: Vec<Vec<u8>> = rows.iter().map(|x| reference::literals_from_features(x)).collect();
    let pjrt_preds = infer_exe.infer_rows(&model, &lit_rows)?;
    for (i, x) in rows.iter().enumerate() {
        let lits = reference::literals_from_features(x);
        let dense = reference::predict_dense(&model, &lits);
        assert_eq!(accel_preds[i], dense, "simulator != dense reference");
        assert_eq!(pjrt_preds[i], dense, "PJRT artifact != dense reference");
    }
    println!("[verify] accelerator == dense reference == PJRT Pallas artifact (32/32)");

    let acc_clean = svc.measure_accuracy(&probe.xs, &probe.ys)?;
    println!("[deploy] clean accuracy on Base accelerator: {acc_clean:.3}");

    // --- 3/4. Drift arrives; the loop recalibrates. -----------------------
    let drifted = w.drifted_dataset(1024, 7, 0.30);
    let (dr_train, dr_probe) = drifted.split(0.75);
    let looper = RecalibrationLoop::new(node, 0.75);
    let windows = vec![
        (probe.clone(), train.clone()),
        (dr_probe.clone(), dr_train.clone()),
    ];
    let report = looper.run(&mut svc, &windows)?;

    for (step, acc) in &report.probes {
        println!("[monitor] window {step}: accuracy {acc:.3}");
    }
    anyhow::ensure!(
        report.recalibrations.len() == 1,
        "expected exactly one recalibration, got {}",
        report.recalibrations.len()
    );
    let ev = &report.recalibrations[0];
    println!(
        "[retune] drift detected ({:.3} < 0.75) -> PJRT retrain -> stream reprogram -> {:.3}",
        ev.accuracy_before, ev.accuracy_after
    );
    anyhow::ensure!(ev.accuracy_after > 0.8, "recovery too weak");

    // --- 5. Cost of the runtime reprogram (the paper's headline). --------
    let new_model = looper.node.retrain(&dr_train)?;
    let instrs = isa::encode(&new_model);
    let codec = rttm::accel::stream::StreamCodec::new(rttm::accel::stream::HeaderWidth::W32);
    let words = 2 + codec.instruction_payload_len(instrs.len()) as u64;
    println!(
        "[cost] reprogramming: {} instructions = {} stream words = {:.1} us @ 200 MHz (vs hours of FPGA resynthesis)",
        instrs.len(),
        words,
        words as f64 / 200.0
    );
    println!("OK: full three-layer runtime-retuning loop reproduced");
    Ok(())
}
