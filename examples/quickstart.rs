//! Quickstart: train a tiny TM, compress it to the 16-bit Include ISA,
//! program the simulated eFPGA accelerator over its data stream, and
//! classify a batch — the whole paper in ~80 lines.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use rttm::accel::core::{AccelConfig, Core};
use rttm::coordinator::TrainingNode;
use rttm::datasets::synth::SynthSpec;
use rttm::isa;
use rttm::tm::reference;
use rttm::TMShape;

fn main() -> anyhow::Result<()> {
    // 1. A small workload: 16 Boolean features, 2 classes.
    let shape = TMShape::synthetic(16, 2, 10);
    let data = SynthSpec::new(16, 2, 512).noise(0.08).seed(7).generate();
    let (train, test) = data.split(0.8);

    // 2. Train on the "Model Training Node" (pure rust backend here;
    //    see runtime_retuning.rs for the PJRT/JAX path).
    let node = TrainingNode::native(shape.clone());
    let model = node.retrain(&train)?;
    println!(
        "trained: {} includes of {} TAs ({:.1}% sparse)",
        model.include_count(),
        shape.total_tas(),
        100.0 * model.sparsity()
    );

    // 3. Compress to the Include-instruction stream (Fig 3).
    let instrs = isa::encode(&model);
    println!(
        "compressed: {} x 16-bit instructions ({} bytes vs {} dense TA bits)",
        instrs.len(),
        2 * instrs.len(),
        shape.total_tas()
    );

    // 4. Program the accelerator through its stream protocol (Fig 4).
    let mut accel = Core::new(AccelConfig::base());
    let codec = accel.codec;
    let mut words = Vec::new();
    words.extend(codec.instruction_header(shape.classes, shape.clauses, instrs.len())?);
    words.extend(codec.pack_instructions(&instrs));
    accel.feed_stream(&words)?;
    println!("programmed: {} stream words, no resynthesis", words.len());

    // 5. Classify one 32-datapoint batch (bit-sliced, Fig 4.5).
    let rows: Vec<Vec<u8>> = test.xs[..32].to_vec();
    let preds = accel.run_rows(&rows)?;
    let correct = preds.iter().zip(&test.ys).filter(|(p, y)| p == y).count();
    println!("batch accuracy: {}/32", correct);

    // 6. Check the accelerator agrees with the dense reference model.
    for (x, &p) in rows.iter().zip(&preds) {
        let lits = reference::literals_from_features(x);
        assert_eq!(p, reference::predict_dense(&model, &lits));
    }
    println!("accelerator == dense reference on all 32 datapoints");

    // 7. Timing card (simulated cycles -> real time at 200 MHz).
    let packed = isa::pack_features(&rows);
    let r = accel.run_batch(&packed)?;
    let us = accel.batch_latency_us(&r.cycles);
    println!(
        "batch latency: {} cycles = {:.2} us @ {} MHz ({:.3} us/datapoint, {:.0} inf/s)",
        r.cycles.total(),
        us,
        accel.cfg.freq_mhz,
        us / 32.0,
        32.0 * 1e6 / us
    );
    Ok(())
}
