//! Keyword spotting (the paper's KWS-6 workload: Google Speech Commands
//! "yes/no/up/down/left/right") with *thermometer booleanization* of a
//! continuous MFCC-like front-end — the full edge pipeline:
//!
//!   continuous sensor frames -> quantile thermometer bits -> TM ->
//!   compressed ISA -> accelerator, including a task update at runtime
//!   (adding a 7th keyword class by reprogramming, the Fig 8 "add an
//!   additional class" scenario).
//!
//! ```sh
//! cargo run --release --example keyword_spotting
//! ```

use rttm::accel::core::{AccelConfig, Core};
use rttm::coordinator::TrainingNode;
use rttm::datasets::synth::{SynthSpec, XorShift64Star};
use rttm::tm::booleanize::ThermometerEncoder;
use rttm::TMShape;

/// Synthesize continuous "MFCC" frames: per-class Gaussian-ish channel
/// means + noise (stands in for Speech Commands audio, DESIGN.md
/// §Substitutions).
fn synth_mfcc(classes: usize, channels: usize, n: usize, seed: u64) -> (Vec<Vec<f64>>, Vec<usize>) {
    let mut rng = XorShift64Star::new(seed);
    let means: Vec<Vec<f64>> = (0..classes)
        .map(|_| (0..channels).map(|_| rng.next_f64() * 4.0 - 2.0).collect())
        .collect();
    let mut xs = Vec::with_capacity(n);
    let mut ys = Vec::with_capacity(n);
    for _ in 0..n {
        let c = rng.below(classes as u64) as usize;
        ys.push(c);
        xs.push(
            means[c]
                .iter()
                .map(|m| m + (rng.next_f64() - 0.5) * 1.6)
                .collect(),
        );
    }
    (xs, ys)
}

fn booleanize(enc: &ThermometerEncoder, xs: &[Vec<f64>]) -> Vec<Vec<u8>> {
    xs.iter().map(|x| enc.encode(x)).collect()
}

fn accuracy(preds: &[usize], ys: &[usize]) -> f64 {
    preds.iter().zip(ys).filter(|(p, y)| p == y).count() as f64 / ys.len() as f64
}

fn main() -> anyhow::Result<()> {
    const CHANNELS: usize = 50; // 50 MFCC-ish channels
    const BITS: usize = 7; // 7-level thermometer -> 350 features (kws6 dims)

    // --- 6-keyword task. --------------------------------------------------
    let (raw, ys) = synth_mfcc(6, CHANNELS, 1536, 42);
    let enc = ThermometerEncoder::fit(&raw, BITS);
    let xb = booleanize(&enc, &raw);
    println!(
        "booleanized: {} channels x {} thermometer bits = {} features",
        CHANNELS,
        BITS,
        enc.features_out()
    );

    let shape = TMShape {
        name: "kws6".into(),
        features: enc.features_out(),
        classes: 6,
        clauses: 150,
        t: 30,
        s: 6.0,
        train_batch: 32,
        n_states: 128,
    };
    let mut data = SynthSpec::new(shape.features, 6, 0).generate(); // container
    data.xs = xb;
    data.ys = ys;
    let (train, test) = data.split(0.8);

    let node = TrainingNode::native(shape.clone());
    let model6 = node.retrain(&train)?;

    // KWS models are the largest here (350 features x 150 clauses); the
    // default single-core instruction memory is too shallow.  This is
    // exactly the Fig 6 deploy-time choice: provision deeper memories
    // (more BRAM/LUT/power, lower f_max) for more tunability headroom.
    let cfg = AccelConfig::single_core().with_depths(65536, 8192);
    let res = rttm::model_cost::estimate(&cfg);
    println!(
        "deploy-time memory customization: instr depth 65536 -> {} LUTs, {} BRAMs, {:.1} MHz",
        res.luts, res.brams, res.freq_mhz
    );
    let mut accel = Core::new(cfg);
    accel.program_model(&model6)?;
    let mut preds = Vec::new();
    for chunk in test.xs.chunks(32) {
        preds.extend(accel.run_rows(chunk)?);
    }
    println!(
        "6-keyword accuracy on accelerator: {:.3} ({} instructions)",
        accuracy(&preds, &test.ys),
        accel.instruction_count()
    );

    // --- Task update at runtime: a 7th keyword appears. -------------------
    // New labeled data with 7 classes; retrain; reprogram the SAME
    // accelerator — different class count, no resynthesis (Fig 8).
    let (raw7, ys7) = synth_mfcc(7, CHANNELS, 1792, 43);
    let enc7 = ThermometerEncoder::fit(&raw7, BITS);
    let mut data7 = SynthSpec::new(enc7.features_out(), 7, 0).generate();
    data7.xs = booleanize(&enc7, &raw7);
    data7.ys = ys7;
    let (train7, test7) = data7.split(0.8);

    let mut shape7 = shape.clone();
    shape7.classes = 7;
    shape7.name = "kws7".into();
    let node7 = TrainingNode::native(shape7);
    let model7 = node7.retrain(&train7)?;

    accel.program_model(&model7)?; // <- the runtime architecture change
    let mut preds7 = Vec::new();
    for chunk in test7.xs.chunks(32) {
        preds7.extend(accel.run_rows(chunk)?);
    }
    println!(
        "7-keyword accuracy after runtime task update: {:.3} (classes 6 -> 7, same bitstream)",
        accuracy(&preds7, &test7.ys)
    );
    anyhow::ensure!(accuracy(&preds7, &test7.ys) > 0.7, "7-class task failed");
    println!("OK: class count changed at runtime via stream reprogramming only");
    Ok(())
}
