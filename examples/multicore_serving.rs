//! Multi-core serving (Fig 7): class-parallel inference behind the
//! replica-pool service front-end, with latency/throughput accounting
//! for every configuration — the serving-side story of the paper.
//!
//! Two axes of parallelism compose here:
//! * *inside* a request, the 5-core engine walks class partitions in
//!   parallel (Fig 7, simulated cycles AND host threads);
//! * *across* requests, the replica pool fans independent requests out
//!   to N engine replicas behind one shared queue, reprogrammed in
//!   lockstep by the version fence (EXPERIMENTS.md §Serving).
//!
//! Uses the sensorless-drives workload (11 classes — the case where
//! class partitioning pays off most; Table 2 notes M wins here).
//!
//! ```sh
//! cargo run --release --example multicore_serving
//! ```

use rttm::accel::core::AccelConfig;
use rttm::accel::engine as sched;
use rttm::accel::multicore::{MultiCore, ParallelMode};
use rttm::coordinator::autotune::{AutotuneConfig, AutotuneEvent, Autotuner};
use rttm::coordinator::server::spawn_pool;
use rttm::coordinator::{Engine, EngineSpec, TrainingNode};
use rttm::datasets::workloads::{workload, DriftSchedule};
use rttm::model_cost::energy::EnergyModel;
use rttm::model_cost::resources::ResourceBudget;

fn main() -> anyhow::Result<()> {
    let w = workload("sensorless")?;
    let node = TrainingNode::native(w.shape.clone());
    let model = node.retrain(&w.dataset(1024, 7))?;
    println!(
        "model: {} instructions over {} classes",
        rttm::isa::instruction_count(&model),
        w.shape.classes
    );

    let requests: Vec<Vec<Vec<u8>>> = (0..64)
        .map(|i| w.dataset(32, 100 + i as u64).xs)
        .collect();

    // Sensorless models run ~12k instructions — beyond the stock base
    // build's 8192-entry instruction memory, so the B/S deployments here
    // use the Fig 6 deeper-memory customization (the paper: "BRAMs ...
    // over-provisioned for more tunability").  The 5-core build splits
    // classes, so each core's stock memory suffices.
    let base_deep = AccelConfig::base().with_depths(16384, 2048);
    let single_deep = AccelConfig::single_core().with_depths(32768, 8192);

    println!(
        "\n{:<14} {:>12} {:>14} {:>14} {:>12} {:>12}",
        "engine", "sim_us/batch", "per_dp_us", "inf/s(sim)", "uJ/batch", "host_rps"
    );
    for (label, engine, em) in [
        (
            "base",
            Engine::custom(base_deep.clone()),
            EnergyModel::for_config(&base_deep),
        ),
        (
            "single_core",
            Engine::custom(single_deep.clone()),
            EnergyModel::for_config(&single_deep),
        ),
        (
            "5-core",
            Engine::five_core(),
            EnergyModel::for_multicore(&AccelConfig::multicore_core(), 5),
        ),
    ] {
        let freq = engine.freq_mhz();
        // Single replica per engine here — this table compares the
        // *engines*; the pool's request-level scaling is shown below.
        let (handle, mut join) = spawn_pool(engine.to_spec(), 1);
        handle.program(model.clone())?;

        let t0 = std::time::Instant::now();
        // 4 concurrent clients hammering the shared queue.
        let mut clients = Vec::new();
        for c in 0..4usize {
            let h = handle.clone();
            let reqs = requests.clone();
            clients.push(std::thread::spawn(move || {
                for (i, r) in reqs.iter().enumerate() {
                    if i % 4 == c {
                        h.infer(r.clone()).unwrap();
                    }
                }
            }));
        }
        for c in clients {
            c.join().unwrap();
        }
        let wall = t0.elapsed();
        let stats = handle.stats()?;
        handle.shutdown();
        join.join();

        let us_per_batch = stats.simulated_us(freq) / stats.batches as f64;
        println!(
            "{:<14} {:>12.2} {:>14.3} {:>14.0} {:>12.3} {:>12.0}",
            label,
            us_per_batch,
            us_per_batch / 32.0,
            32.0 * 1e6 / us_per_batch,
            em.energy_uj(us_per_batch),
            stats.batches as f64 / wall.as_secs_f64(),
        );
    }

    println!("\nNote: 5-core batch latency ~ max(core walk) + merge — the paper's");
    println!("class-level parallelism (Fig 7), bounded by the heaviest class share.");

    // --- Host-side parallel serving: the batch scheduler drives the
    // 5-core build with one thread per core across a whole batch
    // stream (accel::engine), so the class-level parallelism of Fig 7
    // also shows up as host wall-clock, not just simulated cycles.
    println!("\n=== batch scheduler: 5-core host scheduling (run_batches) ===");
    let rows: Vec<Vec<u8>> = (0..64u64)
        .flat_map(|i| w.dataset(32, 200 + i).xs)
        .collect();
    let deep = AccelConfig::multicore_core().with_depths(16384, 2048);
    let mut expected: Option<Vec<usize>> = None;
    for (label, mode) in [("serial", ParallelMode::Serial), ("threads", ParallelMode::Threads)] {
        let mut mc = MultiCore::new(5, deep.clone()).with_parallel(mode);
        mc.program_model(&model)?;
        let (preds, stats) = sched::classify_rows_multicore(&mut mc, &rows)?;
        match &expected {
            None => expected = Some(preds),
            // Host scheduling must never change a single prediction.
            Some(e) => assert_eq!(&preds, e, "scheduling changed results"),
        }
        println!(
            "{:<8} {:>8.1} ms wall  {:>10.0} inferences/s host  {:>10.1} us simulated",
            label,
            stats.wall.as_secs_f64() * 1e3,
            stats.host_inferences_per_s(),
            stats.simulated_us(deep.freq_mhz),
        );
    }

    // --- Replica pool: request-level scaling across engine replicas.
    // Each replica is a full engine (here the deep base build); the
    // shared queue fans concurrent requests across them, and
    // `program` swaps every replica behind the version fence before
    // returning — no request ever runs on a mixed-version pool.
    println!("\n=== replica pool: single worker vs N replicas ===");
    let replicas = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .clamp(2, 8);
    let pool_spec = EngineSpec::custom(base_deep.clone());
    for (label, n) in [("1 replica", 1), ("pool", replicas)] {
        let (handle, mut join) = spawn_pool(pool_spec.clone(), n);
        handle.program(model.clone())?;
        let t0 = std::time::Instant::now();
        let mut clients = Vec::new();
        for c in 0..replicas {
            let h = handle.clone();
            let reqs = requests.clone();
            clients.push(std::thread::spawn(move || {
                for (i, r) in reqs.iter().enumerate() {
                    if i % replicas == c {
                        h.infer(r.clone()).unwrap();
                    }
                }
            }));
        }
        for c in clients {
            c.join().unwrap();
        }
        let wall = t0.elapsed();
        let stats = handle.stats()?;
        handle.shutdown();
        join.join();
        println!(
            "{:<10} ({} workers): {:>8.1} ms wall  {:>10.0} requests/s host",
            label,
            n,
            wall.as_secs_f64() * 1e3,
            stats.batches as f64 / wall.as_secs_f64(),
        );
    }
    println!("\nThe pool multiplies *host* request throughput; per-request");
    println!("simulated latency (the hardware's) is unchanged — each replica");
    println!("models one accelerator.");

    // --- Live autotune: drift arrives mid-serving; the monitor detects
    // it (hysteresis — one noisy window never retunes), a background
    // shadow search retrains candidate shapes under a LUT/BRAM/power
    // budget, and the winner hot-swaps through the same version fence
    // the requests above used.  Traffic keeps flowing throughout.
    println!("\n=== live autotune: abrupt drift on the serving pool ===");
    let drift_sched = DriftSchedule::abrupt(8, 192, 4, 0.4).seed(5);
    // Fresh draws past the monitored stream — the windows measure
    // generalization, not the training set.
    let first_model = node.retrain(&drift_sched.training_set(&w, 384))?;
    // Instruction-memory headroom: retrained candidates can carry more
    // includes than the first model (the paper's "over-provisioned for
    // more tunability later").
    let tune_spec = EngineSpec::custom(rttm::model_cost::resources::provisioned_config(
        &first_model,
        2,
    ));
    let (handle, mut join) = spawn_pool(tune_spec, replicas.min(4));
    let budget = ResourceBudget::unlimited().with_brams(20).with_watts(0.5);
    let mut tune_cfg = AutotuneConfig::new(budget);
    tune_cfg.accuracy_floor = 0.80;
    tune_cfg.epochs = 2;
    tune_cfg.retrain_corpus = 384;
    let mut tuner = Autotuner::new(handle.clone(), w.shape.clone(), tune_cfg);
    tuner.install(first_model)?;
    for (step, win) in drift_sched.stream(&w).iter().enumerate() {
        // Concurrent traffic during every window, retune included.
        let h = handle.clone();
        let rows = win.xs[..32.min(win.xs.len())].to_vec();
        let client = std::thread::spawn(move || h.infer(rows).map(|p| p.len()));
        let stats = tuner.observe_window(&win.xs, &win.ys)?;
        println!(
            "window {step}  drift={:.2}  acc={:.3}  margin={:>7.2}  v{}  [{}]",
            drift_sched.drift_at(step),
            stats.accuracy.unwrap_or(f64::NAN),
            stats.mean_margin,
            stats.model_version,
            tuner.phase_name(),
        );
        if tuner.is_searching() {
            tuner.finish_pending_search()?;
        }
        client.join().unwrap()?;
    }
    for e in &tuner.report.events {
        if let AutotuneEvent::Swapped { window, version, instructions, luts, brams, watts, .. } = e
        {
            println!(
                "SWAPPED at window {window}: v{version}, {instructions} instructions, \
                 {luts} LUTs / {brams} BRAMs / {watts:.3} W — no resynthesis, no downtime"
            );
        }
    }
    handle.shutdown();
    join.join();
    Ok(())
}
